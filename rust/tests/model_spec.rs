//! docs/MODEL_FORMAT.md ↔ `serve/scoring.rs` consistency.
//!
//! The model-format document is normative, so it must not drift from
//! the code. Like `tests/docs_spec.rs` for the store format, this
//! suite parses the spec's markdown tables (header fields, flag
//! registry) and verifies every claimed offset, size, and constant
//! against the real encoder — by probing an encoded header with
//! sentinel values, not by trusting a second copy of the numbers.

use ranksvm::serve::scoring::{
    ModelHeader, MODEL_CHECKSUM_FIELD, MODEL_FLAG_HAS_NORMS, MODEL_HEADER_LEN, MODEL_KNOWN_FLAGS,
    MODEL_MAGIC, MODEL_N_SECTIONS, MODEL_OFFSETS_START, MODEL_VERSION,
};

/// One parsed `| offset | size | `name` … |` table row.
#[derive(Debug)]
struct Row {
    offset: usize,
    size: usize,
    name: String,
}

fn spec_text() -> String {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../docs/MODEL_FORMAT.md");
    std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("read {path}: {e} — the normative spec must exist"))
}

/// Extract the backticked token of a markdown cell ("`dim` — …" → "dim").
fn backticked(cell: &str) -> Option<String> {
    let start = cell.find('`')? + 1;
    let end = start + cell[start..].find('`')?;
    Some(cell[start..end].to_string())
}

/// Collect numeric table rows under the section whose heading contains
/// `heading` (until the next heading).
fn table_rows(doc: &str, heading: &str) -> Vec<Row> {
    let mut in_section = false;
    let mut rows = Vec::new();
    for line in doc.lines() {
        if line.starts_with('#') {
            in_section = line.contains(heading);
            continue;
        }
        if !in_section || !line.starts_with('|') {
            continue;
        }
        let cells: Vec<&str> = line.split('|').map(str::trim).collect();
        // A well-formed row splits into ["", offset, size, field, ""].
        if cells.len() < 5 {
            continue;
        }
        let (Ok(offset), Ok(size)) = (cells[1].parse::<usize>(), cells[2].parse::<usize>())
        else {
            continue; // separator / header rows
        };
        let Some(name) = backticked(cells[3]) else { continue };
        rows.push(Row { offset, size, name });
    }
    rows
}

fn find<'a>(rows: &'a [Row], name: &str) -> &'a Row {
    rows.iter()
        .find(|r| r.name == name)
        .unwrap_or_else(|| panic!("spec table is missing a `{name}` row: {rows:?}"))
}

/// Header with a distinct sentinel in every field, so a probe at a
/// documented offset can only match the field the doc claims is there.
fn sentinel_header() -> ModelHeader {
    ModelHeader {
        dim: 0x1111_1111_1111_1111,
        flags: 0x2222_2222_2222_2222,
        checksum: 0x3333_3333_3333_3333,
        offsets: [0x0101_0101_0101_0101, 0x0202_0202_0202_0202],
    }
}

fn u64_at(bytes: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap())
}

#[test]
fn header_table_offsets_match_the_encoder() {
    let doc = spec_text();
    let rows = table_rows(&doc, "Header");
    let h = sentinel_header();
    let bytes = h.encode();

    let magic = find(&rows, "magic");
    assert_eq!((magic.offset, magic.size), (0, MODEL_MAGIC.len()));
    assert_eq!(&bytes[magic.offset..magic.offset + magic.size], &MODEL_MAGIC);

    let version = find(&rows, "version");
    assert_eq!((version.offset, version.size), (7, 1));
    assert_eq!(bytes[version.offset], MODEL_VERSION);

    // Every u64 field: the sentinel must sit at the documented offset,
    // proving the doc describes the real encoding.
    for (name, sentinel) in [("dim", h.dim), ("flags", h.flags), ("checksum", h.checksum)] {
        let row = find(&rows, name);
        assert_eq!(row.size, 8, "{name}");
        assert_eq!(u64_at(&bytes, row.offset), sentinel, "{name} is not at offset {}", row.offset);
    }
    let checksum = find(&rows, "checksum");
    assert_eq!(checksum.offset, MODEL_CHECKSUM_FIELD.start);
    assert_eq!(checksum.offset + checksum.size, MODEL_CHECKSUM_FIELD.end);

    let offsets = find(&rows, "section_offsets");
    assert_eq!((offsets.offset, offsets.size), (MODEL_OFFSETS_START, 8 * MODEL_N_SECTIONS));
    for (k, &sentinel) in h.offsets.iter().enumerate() {
        assert_eq!(u64_at(&bytes, offsets.offset + 8 * k), sentinel, "section offset {k}");
    }

    let reserved = find(&rows, "reserved");
    assert_eq!(reserved.offset, MODEL_OFFSETS_START + 8 * MODEL_N_SECTIONS);
    assert_eq!(reserved.offset + reserved.size, MODEL_HEADER_LEN);
    assert!(bytes[reserved.offset..MODEL_HEADER_LEN].iter().all(|&b| b == 0));

    // The documented table covers the whole header, gap-free.
    let mut covered: Vec<(usize, usize)> = rows.iter().map(|r| (r.offset, r.size)).collect();
    covered.sort_unstable();
    let mut cursor = 0usize;
    for (off, size) in covered {
        assert_eq!(off, cursor, "header table has a gap or overlap at byte {cursor}");
        cursor = off + size;
    }
    assert_eq!(cursor, MODEL_HEADER_LEN, "header table does not cover the whole header");

    // Prose constants.
    assert!(doc.contains(&format!("{MODEL_HEADER_LEN}-byte header")), "header size prose");
    assert!(doc.contains(&format!("version {MODEL_VERSION}")), "version prose");
}

#[test]
fn flag_registry_matches_the_constants() {
    let doc = spec_text();
    // Parse `| bit | mask | `NAME` | …` rows of the registry table.
    let mut masks = std::collections::HashMap::new();
    for line in doc.lines() {
        if !line.starts_with('|') || !line.contains("0x") {
            continue;
        }
        let cells: Vec<&str> = line.split('|').map(str::trim).collect();
        if cells.len() < 5 {
            continue;
        }
        let Some(hex) = cells[2].strip_prefix("0x") else { continue };
        let Ok(mask) = u64::from_str_radix(hex, 16) else { continue };
        if let Some(name) = backticked(cells[3]) {
            masks.insert(name, mask);
        }
    }
    assert_eq!(masks.get("HAS_NORMS"), Some(&MODEL_FLAG_HAS_NORMS), "{masks:?}");
    assert_eq!(
        masks.values().fold(0u64, |a, &m| a | m),
        MODEL_KNOWN_FLAGS,
        "the registry must list exactly the known flag bits"
    );
}

#[test]
fn sections_table_matches_the_derived_lengths() {
    let doc = spec_text();
    // The sections table documents per-dim lengths `n × 8` for both
    // sections; probe the real derivation at a sentinel dim.
    let h = ModelHeader {
        dim: 13,
        flags: MODEL_FLAG_HAS_NORMS,
        checksum: 0,
        offsets: [MODEL_HEADER_LEN as u64, MODEL_HEADER_LEN as u64 + 13 * 8],
    };
    assert_eq!(h.section_len(0), 13 * 8);
    assert_eq!(h.section_len(1), 13 * 8);
    let plain = ModelHeader { flags: 0, ..h };
    assert_eq!(plain.section_len(1), 0, "norms section is empty without HAS_NORMS");
    for needle in ["| 0 | `weights` | n × 8 |", "| 1 | `norms` | n × 8 |"] {
        assert!(doc.contains(needle), "sections table is missing {needle:?}");
    }
}
