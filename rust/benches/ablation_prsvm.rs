//! Ablation C — materialized-pair PRSVM vs our linearithmic `prsvm-tree`
//! (sum-augmented tree; DESIGN.md §7b). Oracle-level costs and full
//! training runs across m with r ≈ m: the pair list is O(m²) in time and
//! memory, the tree oracle O(m log m)/O(m).

mod common;

use common::{fmt_secs, header, record};
use ranksvm::coordinator::{train, Method, TrainConfig};
use ranksvm::data::synthetic;
use ranksvm::losses::{count_comparable_pairs, SquaredPairOracle, SquaredTreeOracle};
use ranksvm::util::json::Json;

fn main() {
    header("Ablation C1: squared-hinge oracle eval cost (r ≈ m)");
    println!("{:>9} {:>14} {:>14} {:>14}", "m", "pairs-eval", "tree-eval", "pairs-mem");
    for m in [1000usize, 2000, 4000, 8000, 16000] {
        let ds = synthetic::cadata_like(m, 500);
        let p: Vec<f64> = ds.y.iter().map(|v| v * 0.4).collect();
        let n = count_comparable_pairs(&ds.y) as f64;
        let pair_cap = 16000;
        let (t_pairs, mem) = if m <= pair_cap {
            let mut o = SquaredPairOracle::new(&ds.y);
            std::hint::black_box(o.eval_full(&p, n));
            let t = std::time::Instant::now();
            for _ in 0..3 {
                std::hint::black_box(o.eval_full(&p, n));
            }
            (Some(t.elapsed().as_secs_f64() / 3.0), o.mem_bytes())
        } else {
            (None, 0)
        };
        let mut o = SquaredTreeOracle::new();
        std::hint::black_box(o.eval_full(&p, &ds.y, n));
        let t = std::time::Instant::now();
        for _ in 0..3 {
            std::hint::black_box(o.eval_full(&p, &ds.y, n));
        }
        let t_tree = t.elapsed().as_secs_f64() / 3.0;
        println!(
            "{:>9} {:>14} {:>14} {:>13.1}M",
            m,
            t_pairs.map(fmt_secs).unwrap_or_else(|| "(skipped)".into()),
            fmt_secs(t_tree),
            mem as f64 / 1e6
        );
        record(
            "ablation_prsvm",
            Json::obj(vec![
                ("m", m.into()),
                ("pairs_secs", t_pairs.map(Json::Num).unwrap_or(Json::Null)),
                ("tree_secs", t_tree.into()),
                ("pairs_mem_bytes", mem.into()),
            ]),
        );
    }

    header("Ablation C2: full truncated-Newton training, prsvm vs prsvm-tree");
    println!("{:>9} {:>14} {:>14}", "m", "prsvm", "prsvm-tree");
    for m in [1000usize, 2000, 4000, 8000] {
        let ds = synthetic::cadata_like(m, 501);
        print!("{m:>9}");
        for method in [Method::Prsvm, Method::PrsvmTree] {
            if method == Method::Prsvm && m > 4000 {
                print!(" {:>14}", "(skipped)");
                continue;
            }
            let cfg = TrainConfig { method, lambda: 0.1, epsilon: 1e-3, ..Default::default() };
            let t = std::time::Instant::now();
            let out = train(&ds, &cfg).expect("train");
            let secs = t.elapsed().as_secs_f64();
            print!(" {:>14}", fmt_secs(secs));
            record(
                "ablation_prsvm",
                Json::obj(vec![
                    ("m", m.into()),
                    ("method", method.name().into()),
                    ("train_secs", secs.into()),
                    ("objective", out.objective.into()),
                ]),
            );
        }
        println!();
    }
    println!("\nExpected: identical objectives; tree column linearithmic, pairs");
    println!("column quadratic in both time and memory (Fig.-3 mechanism).");
}
