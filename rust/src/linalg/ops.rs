//! Dense vector kernels used throughout the optimizers and losses.
//!
//! Written as straightforward slice loops; rustc auto-vectorizes the
//! chunked forms. `dot` is the innermost hot operation of the native
//! compute backend (score matvec) and of the BMRM inner QP. The argsort
//! family implements the `π` construction of Algorithm 3, including the
//! deterministic parallel merge sort [`par_argsort_into`] that removes
//! the oracle's last serial `O(m log m)` term.

use crate::linalg::simd;
use crate::runtime::pool::{Task, WorkerPool};

/// Dot product. Panics if lengths differ (debug) / truncates never.
///
/// Routed through the [`simd`] dispatch point. The scalar reference is
/// this function's historical 4-accumulator body verbatim and the AVX2
/// path keeps one accumulator per lane with the same
/// `((a₀+a₁)+a₂)+a₃` fold, so the result is bit-identical on either
/// path (pinned by `tests/kernels.rs`).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    simd::dense_dot(simd::active(), a, b)
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `y = x` (copy).
#[inline]
pub fn copy(x: &[f64], y: &mut [f64]) {
    y.copy_from_slice(x);
}

/// `x *= alpha`.
#[inline]
pub fn scal(alpha: f64, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// Squared L2 norm.
#[inline]
pub fn norm_sq(x: &[f64]) -> f64 {
    dot(x, x)
}

/// L2 norm.
#[inline]
pub fn norm(x: &[f64]) -> f64 {
    norm_sq(x).sqrt()
}

/// The canonical argsort order: ascending by `f64::total_cmp` on the
/// value, ties broken by ascending index. This is a *strict total* order
/// on positions — no two positions compare equal — so the sorted
/// permutation is unique, and every argsort in the crate (serial or
/// parallel, any algorithm) produces bit-identical output. `total_cmp`
/// also makes the order total over NaN/±0.0 payloads, so a rogue score
/// can no longer panic a sort mid-training (NaNs order after +∞).
#[inline]
fn key_cmp(v: &[f64], a: usize, b: usize) -> std::cmp::Ordering {
    v[a].total_cmp(&v[b]).then(a.cmp(&b))
}

/// Argsort: indices that sort `v` ascending (stable). This is the
/// `π` construction on line 4 of Algorithm 3.
pub fn argsort(v: &[f64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..v.len()).collect();
    idx.sort_unstable_by(|&a, &b| key_cmp(v, a, b));
    idx
}

/// Argsort reusing a caller-owned index buffer (avoids the per-iteration
/// allocation in the BMRM loop — §Perf optimization).
pub fn argsort_into(v: &[f64], idx: &mut Vec<usize>) {
    idx.clear();
    idx.extend(0..v.len());
    idx.sort_unstable_by(|&a, &b| key_cmp(v, a, b));
}

/// Adaptive chunk count for the parallel work plans (ROADMAP "adaptive
/// chunk counts"): `clamp(4 × n_threads, 4, 64)`, derived once per
/// trainer from the persistent pool's size. Four chunks per worker give
/// the work-stealing scheduler room to balance uneven chunk costs —
/// every chunk is submitted as an individually stealable task — without
/// the overhead of hundreds of tiny tasks; the clamp keeps tiny and
/// huge pools sane. Only plans whose results are *exact* for any
/// chunk count use this — the argsort's permutation is the unique one
/// under a strict total order and the sharded oracle's counts are exact
/// integers. The parallel gradient reduction keeps its fixed plan
/// (`compute::GRAD_CHUNKS`): its float partial sums re-associate with
/// the chunk plan, and bit-identity across thread counts is a contract.
pub fn adaptive_chunks(n_threads: usize) -> usize {
    (4 * n_threads).clamp(4, 64)
}

/// Below this length the serial sort wins over chunk + merge scheduling.
pub const PAR_SORT_MIN: usize = 1024;

/// Caller-owned scratch for [`par_argsort_into`], reused across calls so
/// the parallel path stops allocating once warm (DETERMINISM.md
/// checklist: "hoist allocations out of the steady-state loop"). Holds
/// the two buffers whose size scales with the input — the O(m) ping-pong
/// merge destination and the chunk boundary table. The per-level task
/// boxes are *not* hoistable: `WorkerPool::run` consumes its task vector
/// by value, and at ≤ 64 chunks they are noise next to the O(m) buffers.
#[derive(Default)]
pub struct SortScratch {
    /// Ping-pong merge destination (`m` slots).
    pong: Vec<usize>,
    /// Chunk boundary table (`chunks + 1` entries).
    bounds: Vec<usize>,
}

/// Parallel argsort on a [`WorkerPool`]: deterministic merge sort over an
/// [`adaptive_chunks`]-chunk plan (derived from the pool size) with
/// fixed-topology pairwise merges (stride 1, 2, 4, …). Each merge level
/// is cut into one output span per chunk along the same chunk
/// boundaries, located in the two input runs by merge-path co-rank
/// binary searches, and every chunk/span is one individually stealable
/// pool task, so every level keeps all workers busy — including the
/// final whole-array merge that would otherwise re-serialize the
/// sort. Because the comparator is the strict total order of
/// [`argsort_into`] (value, then index), the permutation is
/// **bit-identical to the serial argsort for any thread count** (the
/// chunk count only changes how the unique answer is assembled);
/// `scratch` is the caller-owned [`SortScratch`] reused across BMRM
/// iterations.
pub fn par_argsort_into(
    v: &[f64],
    idx: &mut Vec<usize>,
    scratch: &mut SortScratch,
    pool: &WorkerPool,
) {
    let m = v.len();
    let chunks = adaptive_chunks(pool.n_threads());
    idx.clear();
    idx.extend(0..m);
    if m < PAR_SORT_MIN.max(chunks) || pool.n_threads() <= 1 {
        idx.sort_unstable_by(|&a, &b| key_cmp(v, a, b));
        return;
    }
    scratch.bounds.clear();
    scratch.bounds.extend((0..=chunks).map(|c| c * m / chunks));
    let bounds: &[usize] = &scratch.bounds;

    // Phase 1: sort each chunk independently.
    {
        let mut tasks: Vec<Task> = Vec::with_capacity(chunks);
        let mut rest: &mut [usize] = idx;
        for c in 0..chunks {
            // Move `rest` out before splitting so the tail can be
            // carried to the next iteration.
            let (head, tail) = { rest }.split_at_mut(bounds[c + 1] - bounds[c]);
            tasks.push(Box::new(move || head.sort_unstable_by(|&a, &b| key_cmp(v, a, b))));
            rest = tail;
        }
        pool.run(tasks);
    }

    // Phase 2: pairwise merge levels, ping-ponging between `idx` and
    // the scratch buffer. With ⌈log₂ chunks⌉ odd (e.g. 8 or 32 chunks)
    // the final merge lands in the scratch and one O(m) copy brings it
    // home — noise next to the sort itself.
    scratch.pong.clear();
    scratch.pong.resize(m, 0);
    let mut src: &mut [usize] = idx;
    let mut dst: &mut [usize] = &mut scratch.pong;
    let mut stride = 1;
    let mut in_idx = true;
    while stride < chunks {
        merge_level(v, src, dst, bounds, stride, pool);
        std::mem::swap(&mut src, &mut dst);
        in_idx = !in_idx;
        stride *= 2;
    }
    if !in_idx {
        dst.copy_from_slice(src);
    }
}

/// One merge level: merge run pairs of `stride` chunks from `src` into
/// `dst`, each pair's output cut into spans along the global chunk
/// boundaries so the level parallelizes one-task-per-chunk regardless of
/// how few pairs remain.
fn merge_level(
    v: &[f64],
    src: &[usize],
    dst: &mut [usize],
    bounds: &[usize],
    stride: usize,
    pool: &WorkerPool,
) {
    let n_chunks = bounds.len() - 1;
    let mut tasks: Vec<Task> = Vec::with_capacity(n_chunks);
    let mut rest: &mut [usize] = dst;
    let mut base = 0;
    while base < n_chunks {
        let pair_hi = (base + 2 * stride).min(n_chunks);
        let lo = bounds[base];
        let mid = bounds[(base + stride).min(n_chunks)];
        let hi = bounds[pair_hi];
        for t in base..pair_hi {
            let s0 = bounds[t] - lo;
            let s1 = bounds[t + 1] - lo;
            let i0 = co_rank(v, src, lo, mid, hi, s0);
            let i1 = co_rank(v, src, lo, mid, hi, s1);
            let (j0, j1) = (s0 - i0, s1 - i1);
            let (span, tail) = { rest }.split_at_mut(s1 - s0);
            rest = tail;
            let left = &src[lo + i0..lo + i1];
            let right = &src[mid + j0..mid + j1];
            tasks.push(Box::new(move || merge_runs(v, left, right, span)));
        }
        base += 2 * stride;
    }
    pool.run(tasks);
}

/// Merge-path co-rank: for the pair of sorted runs `src[lo..mid]` (A)
/// and `src[mid..hi]` (B), return the unique `i` such that the first
/// `k` elements of their merge are exactly `A[..i] ∪ B[..k−i]`. Unique
/// because [`key_cmp`] is a strict total order (distinct indices never
/// compare equal), which is what makes the span decomposition exact.
fn co_rank(v: &[f64], src: &[usize], lo: usize, mid: usize, hi: usize, k: usize) -> usize {
    let nl = mid - lo;
    let nr = hi - mid;
    let mut i_lo = k.saturating_sub(nr);
    let mut i_hi = k.min(nl);
    while i_lo < i_hi {
        let i = (i_lo + i_hi) / 2;
        // i < i_hi ≤ min(k, nl) ⇒ A[i] and B[k−i−1] are both in range.
        if key_cmp(v, src[lo + i], src[mid + k - i - 1]) == std::cmp::Ordering::Less {
            i_lo = i + 1;
        } else {
            i_hi = i;
        }
    }
    i_lo
}

/// Sequential two-run merge into `out` under [`key_cmp`].
fn merge_runs(v: &[f64], a: &[usize], b: &[usize], out: &mut [usize]) {
    debug_assert_eq!(a.len() + b.len(), out.len());
    let (mut i, mut j) = (0, 0);
    for slot in out.iter_mut() {
        let take_a = j == b.len()
            || (i < a.len() && key_cmp(v, a[i], b[j]) == std::cmp::Ordering::Less);
        if take_a {
            *slot = a[i];
            i += 1;
        } else {
            *slot = b[j];
            j += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_small_and_remainder() {
        assert_eq!(dot(&[1.0, 2.0, 3.0, 4.0, 5.0], &[1.0; 5]), 15.0);
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(dot(&[2.0], &[3.0]), 6.0);
    }

    #[test]
    fn axpy_and_scal() {
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[3.0, 4.0], &mut y);
        assert_eq!(y, vec![7.0, 9.0]);
        scal(0.5, &mut y);
        assert_eq!(y, vec![3.5, 4.5]);
    }

    #[test]
    fn norms() {
        assert_eq!(norm_sq(&[3.0, 4.0]), 25.0);
        assert_eq!(norm(&[3.0, 4.0]), 5.0);
    }

    #[test]
    fn argsort_orders_and_is_stable() {
        let v = [3.0, 1.0, 2.0, 1.0];
        let idx = argsort(&v);
        assert_eq!(idx, vec![1, 3, 2, 0]); // stable: 1 before 3
        let mut buf = Vec::new();
        argsort_into(&v, &mut buf);
        assert_eq!(buf, idx);
    }

    #[test]
    fn argsort_totals_nan_and_signed_zero() {
        // NaN orders after +∞ under total_cmp instead of panicking.
        let v = [f64::NAN, 2.0, f64::INFINITY, 1.0];
        assert_eq!(argsort(&v), vec![3, 1, 2, 0]);
        // −0.0 orders before +0.0 (total order), not by index.
        let v = [0.0, -0.0, -1.0];
        assert_eq!(argsort(&v), vec![2, 1, 0]);
    }

    fn sort_cases(rng: &mut crate::util::rng::Rng) -> Vec<Vec<f64>> {
        let m = PAR_SORT_MIN + rng.below(4 * PAR_SORT_MIN);
        vec![
            (0..m).map(|_| rng.normal()).collect(),
            // Heavy ties: the index tie-break does the ordering.
            (0..m).map(|_| rng.below(7) as f64).collect(),
            vec![42.0; m],
            // Already sorted / reversed.
            (0..m).map(|i| i as f64).collect(),
            (0..m).map(|i| (m - i) as f64).collect(),
            // Signed zeros and NaNs mixed in.
            (0..m)
                .map(|i| match i % 5 {
                    0 => 0.0,
                    1 => -0.0,
                    2 => f64::NAN,
                    _ => rng.normal(),
                })
                .collect(),
        ]
    }

    #[test]
    fn adaptive_chunk_plan_follows_pool_size() {
        assert_eq!(adaptive_chunks(1), 4);
        assert_eq!(adaptive_chunks(2), 8);
        assert_eq!(adaptive_chunks(3), 12);
        assert_eq!(adaptive_chunks(8), 32);
        assert_eq!(adaptive_chunks(16), 64);
        assert_eq!(adaptive_chunks(128), 64); // clamped
    }

    #[test]
    fn par_argsort_bit_identical_to_serial_for_any_thread_count() {
        let mut rng = crate::util::rng::Rng::new(303);
        for _ in 0..3 {
            for v in sort_cases(&mut rng) {
                let mut expect = Vec::new();
                argsort_into(&v, &mut expect);
                for threads in [1usize, 2, 3, 8] {
                    let pool = WorkerPool::new(threads);
                    let mut idx = Vec::new();
                    let mut scratch = SortScratch::default();
                    par_argsort_into(&v, &mut idx, &mut scratch, &pool);
                    assert_eq!(idx, expect, "{threads} threads, m={}", v.len());
                }
            }
        }
    }

    #[test]
    fn par_argsort_small_inputs_take_serial_path() {
        let pool = WorkerPool::new(4);
        let mut idx = Vec::new();
        let mut scratch = SortScratch::default();
        for v in [vec![], vec![5.0], vec![3.0, 1.0, 2.0, 1.0]] {
            par_argsort_into(&v, &mut idx, &mut scratch, &pool);
            assert_eq!(idx, argsort(&v));
        }
    }

    #[test]
    fn par_argsort_buffers_reused_across_sizes() {
        let pool = WorkerPool::new(4);
        let mut rng = crate::util::rng::Rng::new(304);
        let mut idx = Vec::new();
        let mut scratch = SortScratch::default();
        for m in [PAR_SORT_MIN * 3, 10, PAR_SORT_MIN + 1, PAR_SORT_MIN * 2] {
            let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            par_argsort_into(&v, &mut idx, &mut scratch, &pool);
            assert_eq!(idx, argsort(&v), "m={m}");
        }
        // Steady state: the scratch buffers are warm and at least as
        // large as the biggest parallel input seen so far.
        assert!(scratch.pong.capacity() >= PAR_SORT_MIN * 3);
        assert!(scratch.bounds.capacity() > 0);
    }

    #[test]
    fn dot_matches_naive_randomized() {
        let mut rng = crate::util::rng::Rng::new(5);
        for _ in 0..50 {
            let n = rng.below(200);
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-9 * (1.0 + naive.abs()));
        }
    }
}
