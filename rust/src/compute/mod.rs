//! Pluggable compute backends for the per-iteration linear algebra.
//!
//! The two `O(ms)` operations of every training iteration — the score
//! matvec `p = X·w` and the subgradient assembly `a = Xᵀ·coeffs` — are
//! routed through this trait so the coordinator can execute them either
//! with native Rust kernels ([`NativeBackend`], sparse CSR/CSC or dense)
//! or with the AOT-compiled XLA executables lowered from JAX/Pallas
//! ([`crate::runtime::XlaBackend`]). Python is never on this path: the
//! XLA backend loads pre-built `artifacts/*.hlo.txt`.

use crate::linalg::{CscMatrix, CsrMatrix};

/// Backend interface. `prepare` is called once per dataset so backends
/// can build auxiliary structures (CSC copy, padded dense tiles, device
/// buffers) off the hot path.
pub trait ComputeBackend {
    fn name(&self) -> &'static str;
    /// One-time per-dataset setup.
    fn prepare(&mut self, _x: &CsrMatrix) {}
    /// `p = X·w` (length = rows).
    fn scores(&mut self, x: &CsrMatrix, w: &[f64]) -> Vec<f64>;
    /// `a = Xᵀ·coeffs` (length = cols).
    fn grad(&mut self, x: &CsrMatrix, coeffs: &[f64]) -> Vec<f64>;
}

/// Native Rust kernels. With `use_csc`, the gradient runs over a
/// column-compressed copy (gather instead of scatter) — the "two copies
/// of the data matrix" trade-off the paper describes in its Fig.-3
/// discussion; costs ~2× matrix memory.
pub struct NativeBackend {
    use_csc: bool,
    csc: Option<CscMatrix>,
}

impl NativeBackend {
    pub fn new() -> Self {
        NativeBackend { use_csc: false, csc: None }
    }

    pub fn with_csc() -> Self {
        NativeBackend { use_csc: true, csc: None }
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl ComputeBackend for NativeBackend {
    fn name(&self) -> &'static str {
        if self.use_csc {
            "native+csc"
        } else {
            "native"
        }
    }

    fn prepare(&mut self, x: &CsrMatrix) {
        if self.use_csc {
            self.csc = Some(x.to_csc());
        }
    }

    fn scores(&mut self, x: &CsrMatrix, w: &[f64]) -> Vec<f64> {
        let mut p = vec![0.0; x.rows()];
        x.matvec(w, &mut p);
        p
    }

    fn grad(&mut self, x: &CsrMatrix, coeffs: &[f64]) -> Vec<f64> {
        let mut a = vec![0.0; x.cols()];
        match (&self.csc, self.use_csc) {
            (Some(csc), true) => csc.matvec_t(coeffs, &mut a),
            _ => x.matvec_t(coeffs, &mut a),
        }
        a
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn csr_and_csc_paths_agree() {
        let mut rng = Rng::new(701);
        let mut triplets = Vec::new();
        for i in 0..50 {
            for j in 0..30 {
                if rng.bool(0.2) {
                    triplets.push((i, j, rng.normal()));
                }
            }
        }
        let x = CsrMatrix::from_triplets(50, 30, triplets);
        let w: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let c: Vec<f64> = (0..50).map(|_| rng.normal()).collect();

        let mut plain = NativeBackend::new();
        let mut twocopy = NativeBackend::with_csc();
        plain.prepare(&x);
        twocopy.prepare(&x);

        assert_eq!(plain.scores(&x, &w), twocopy.scores(&x, &w));
        let g1 = plain.grad(&x, &c);
        let g2 = twocopy.grad(&x, &c);
        for (a, b) in g1.iter().zip(&g2) {
            assert!((a - b).abs() < 1e-10);
        }
    }
}
