//! Randomized property tests over the crate's core invariants —
//! the proptest substitute (DESIGN.md §6): seeded xoshiro generation,
//! many iterations, failing inputs printed for replay.

use ranksvm::losses::{
    count_comparable_pairs, PairOracle, RLevelOracle, RankingOracle, SquaredPairOracle, TreeOracle,
};
use ranksvm::metrics;
use ranksvm::rbtree::{FenwickCounter, OsTree, RankCounter};
use ranksvm::util::rng::Rng;

/// Run `f` over `iters` seeded cases; on panic, report the failing seed.
fn for_cases(iters: u64, f: impl Fn(&mut Rng) + std::panic::RefUnwindSafe) {
    for seed in 0..iters {
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(0xABCD_0000 + seed);
            f(&mut rng);
        });
        if let Err(e) = result {
            eprintln!("property failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

/// Property: the tree oracle equals the brute-force pair oracle on
/// arbitrary (p, y) — the heart of Theorem 1.
#[test]
fn prop_tree_equals_pair_oracle() {
    for_cases(60, |rng| {
        let m = 1 + rng.below(200);
        let levels = 1 + rng.below(m); // any tie structure
        let y: Vec<f64> = (0..m).map(|_| rng.below(levels) as f64).collect();
        // Include exact ties and near-margin values in p.
        let p: Vec<f64> = (0..m).map(|_| (rng.below(40) as f64) / 7.0 - 3.0).collect();
        let n = count_comparable_pairs(&y) as f64;
        let mut tree = TreeOracle::new();
        let mut pair = PairOracle::new();
        let a = tree.eval(&p, &y, n);
        let b = pair.eval(&p, &y, n);
        assert_eq!(a.coeffs, b.coeffs);
        assert!((a.loss - b.loss).abs() <= 1e-12 * (1.0 + b.loss));
    });
}

/// Property: all three counting structures agree after arbitrary insert
/// sequences (tree plain/dedup, Fenwick over the same universe).
#[test]
fn prop_counters_agree() {
    for_cases(60, |rng| {
        let n_keys = 1 + rng.below(30);
        let universe: Vec<f64> = (0..n_keys).map(|_| rng.normal()).collect();
        let mut plain = OsTree::new();
        let mut dedup = OsTree::new_dedup();
        let mut fen = FenwickCounter::new(&universe);
        let ops = rng.below(300);
        for _ in 0..ops {
            let k = universe[rng.below(n_keys)];
            plain.insert(k);
            dedup.insert(k);
            fen.insert(k);
        }
        plain.check_invariants();
        dedup.check_invariants();
        for &q in &universe {
            let s = RankCounter::count_smaller(&plain, q);
            assert_eq!(s, RankCounter::count_smaller(&dedup, q));
            assert_eq!(s, RankCounter::count_smaller(&fen, q));
            let l = RankCounter::count_larger(&plain, q);
            assert_eq!(l, RankCounter::count_larger(&dedup, q));
            assert_eq!(l, RankCounter::count_larger(&fen, q));
        }
    });
}

/// Property: subgradient validity — for random w, w', the first-order
/// lower bound R(w') ≥ R(w) + ⟨w' − w, ∇R(w)⟩ holds (convexity + correct
/// subgradient), exercised through score space with X = I.
#[test]
fn prop_subgradient_lower_bounds_risk() {
    for_cases(40, |rng| {
        let m = 2 + rng.below(60);
        let y: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let n = count_comparable_pairs(&y) as f64;
        if n == 0.0 {
            return;
        }
        let p1: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let p2: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let mut tree = TreeOracle::new();
        let at1 = tree.eval(&p1, &y, n);
        let at2 = tree.eval(&p2, &y, n);
        let inner: f64 = at1
            .coeffs
            .iter()
            .zip(p2.iter().zip(&p1))
            .map(|(g, (b, a))| g * (b - a))
            .sum();
        assert!(
            at2.loss + 1e-9 >= at1.loss + inner,
            "subgradient inequality violated: {} < {} + {}",
            at2.loss,
            at1.loss,
            inner
        );
    });
}

/// Property: the same convexity bound for the squared hinge.
#[test]
fn prop_squared_subgradient_lower_bounds() {
    for_cases(30, |rng| {
        let m = 2 + rng.below(40);
        let y: Vec<f64> = (0..m).map(|_| rng.below(5) as f64).collect();
        let n = count_comparable_pairs(&y) as f64;
        if n == 0.0 {
            return;
        }
        let mut o = SquaredPairOracle::new(&y);
        let p1: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let p2: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let a1 = o.eval_full(&p1, n);
        let a2 = o.eval_full(&p2, n);
        let inner: f64 = a1
            .coeffs
            .iter()
            .zip(p2.iter().zip(&p1))
            .map(|(g, (b, a))| g * (b - a))
            .sum();
        assert!(a2.loss + 1e-9 >= a1.loss + inner);
    });
}

/// Property: pairwise error is invariant under strictly monotone
/// transformations of the predictions (ranking-only criterion).
#[test]
fn prop_metric_monotone_invariance() {
    for_cases(40, |rng| {
        let m = 2 + rng.below(80);
        let y: Vec<f64> = (0..m).map(|_| rng.below(6) as f64).collect();
        let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let e1 = metrics::pairwise_error(&p, &y);
        let p2: Vec<f64> = p.iter().map(|v| 3.0 * v + 7.0).collect(); // affine
        let p3: Vec<f64> = p.iter().map(|v| v.exp()).collect(); // nonlinear monotone
        assert!((metrics::pairwise_error(&p2, &y) - e1).abs() < 1e-12);
        assert!((metrics::pairwise_error(&p3, &y) - e1).abs() < 1e-12);
    });
}

/// Property: r-level oracle equals the tree oracle across tie regimes
/// including the degenerate single-level case.
#[test]
fn prop_rlevel_equals_tree() {
    for_cases(40, |rng| {
        let m = 1 + rng.below(120);
        let r = 1 + rng.below(12);
        let y: Vec<f64> = (0..m).map(|_| rng.below(r) as f64).collect();
        let p: Vec<f64> = (0..m).map(|_| rng.normal() * 2.0).collect();
        let n = count_comparable_pairs(&y) as f64;
        let mut a = RLevelOracle::new();
        let mut b = TreeOracle::new();
        let oa = a.eval(&p, &y, n);
        let ob = b.eval(&p, &y, n);
        assert_eq!(oa.coeffs, ob.coeffs);
    });
}

/// Property: loss is translation-invariant in scores (only differences
/// p_i − p_j enter eq. 4), and scales the subgradient coherently.
#[test]
fn prop_loss_translation_invariant() {
    for_cases(40, |rng| {
        let m = 2 + rng.below(60);
        let y: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let n = count_comparable_pairs(&y) as f64;
        let shift = rng.range(-5.0, 5.0);
        let p_shifted: Vec<f64> = p.iter().map(|v| v + shift).collect();
        let mut tree = TreeOracle::new();
        let a = tree.eval(&p, &y, n);
        let b = tree.eval(&p_shifted, &y, n);
        assert!((a.loss - b.loss).abs() < 1e-9 * (1.0 + a.loss));
        assert_eq!(a.coeffs, b.coeffs);
    });
}
