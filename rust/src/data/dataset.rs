//! The core dataset container used by every training method and bench.

use super::DatasetView;
use crate::linalg::{CsrMatrix, CsrView};
use crate::util::rng::Rng;

/// A ranking dataset: sparse feature matrix (rows = examples), real-valued
/// utility scores, and optional query ids (document-retrieval setting).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: CsrMatrix,
    pub y: Vec<f64>,
    /// Per-example query id; `None` means one global ranking.
    pub qid: Option<Vec<u64>>,
    /// Human-readable provenance for logs.
    pub name: String,
}

impl Dataset {
    pub fn new(x: CsrMatrix, y: Vec<f64>, qid: Option<Vec<u64>>, name: impl Into<String>) -> Self {
        assert_eq!(x.rows(), y.len(), "feature/label count mismatch");
        if let Some(q) = &qid {
            assert_eq!(q.len(), y.len(), "qid/label count mismatch");
        }
        Dataset { x, y, qid, name: name.into() }
    }

    /// Number of examples `m`.
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Feature dimension `n`.
    pub fn dim(&self) -> usize {
        self.x.cols()
    }

    /// Average non-zero features per example — the paper's `s`.
    pub fn sparsity(&self) -> f64 {
        self.x.avg_nnz_per_row()
    }

    /// Number of distinct utility levels — the paper's `r`.
    pub fn n_levels(&self) -> usize {
        let mut l = self.y.clone();
        l.sort_unstable_by(|a, b| a.total_cmp(b));
        l.dedup();
        l.len()
    }

    /// Take the first `m` examples (the scalability benches' growing
    /// prefixes, mirroring the paper's exponentially growing train sizes).
    pub fn prefix(&self, m: usize) -> Dataset {
        assert!(m <= self.len());
        Dataset {
            x: self.x.row_range(0, m),
            y: self.y[..m].to_vec(),
            qid: self.qid.as_ref().map(|q| q[..m].to_vec()),
            name: format!("{}[:{}]", self.name, m),
        }
    }

    /// Random shuffled split into (train, test) with `test_size` examples
    /// held out. Deterministic given the seed.
    pub fn split(&self, test_size: usize, seed: u64) -> (Dataset, Dataset) {
        assert!(test_size < self.len(), "test split must leave training data");
        let mut idx: Vec<usize> = (0..self.len()).collect();
        let mut rng = Rng::new(seed);
        rng.shuffle(&mut idx);
        let (test_idx, train_idx) = idx.split_at(test_size);
        (self.subset(train_idx, "train"), self.subset(test_idx, "test"))
    }

    /// Gather an arbitrary subset of examples.
    pub fn subset(&self, rows: &[usize], tag: &str) -> Dataset {
        Dataset {
            x: self.x.select_rows(rows),
            y: rows.iter().map(|&i| self.y[i]).collect(),
            qid: self.qid.as_ref().map(|q| rows.iter().map(|&i| q[i]).collect()),
            name: format!("{}/{}", self.name, tag),
        }
    }
}

/// The owned dataset is the canonical [`DatasetView`]; the trainer and
/// friends only ever see the trait, so a memory-mapped store substitutes
/// transparently.
impl DatasetView for Dataset {
    fn x(&self) -> CsrView<'_> {
        self.x.view()
    }

    fn y(&self) -> &[f64] {
        &self.y
    }

    fn qid(&self) -> Option<&[u64]> {
        self.qid.as_deref()
    }

    fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        let x = CsrMatrix::from_triplets(
            4,
            2,
            vec![(0, 0, 1.0), (1, 1, 2.0), (2, 0, 3.0), (3, 1, 4.0)],
        );
        Dataset::new(x, vec![1.0, 2.0, 2.0, 3.0], None, "tiny")
    }

    #[test]
    fn accessors() {
        let d = tiny();
        assert_eq!(d.len(), 4);
        assert_eq!(d.dim(), 2);
        assert_eq!(d.n_levels(), 3);
        assert_eq!(d.sparsity(), 1.0);
    }

    #[test]
    fn prefix_keeps_order() {
        let d = tiny().prefix(2);
        assert_eq!(d.len(), 2);
        assert_eq!(d.y, vec![1.0, 2.0]);
    }

    #[test]
    fn split_partitions_without_overlap() {
        let d = tiny();
        let (train, test) = d.split(1, 7);
        assert_eq!(train.len(), 3);
        assert_eq!(test.len(), 1);
        // label multiset preserved
        let mut all: Vec<f64> = train.y.iter().chain(test.y.iter()).cloned().collect();
        all.sort_unstable_by(|a, b| a.total_cmp(b));
        assert_eq!(all, vec![1.0, 2.0, 2.0, 3.0]);
    }

    #[test]
    fn split_is_deterministic() {
        let d = tiny();
        let (a, _) = d.split(2, 99);
        let (b, _) = d.split(2, 99);
        assert_eq!(a.y, b.y);
    }

    #[test]
    #[should_panic]
    fn mismatched_labels_panic() {
        let x = CsrMatrix::from_triplets(2, 1, vec![]);
        Dataset::new(x, vec![1.0], None, "bad");
    }
}
