//! Inner quadratic program of the bundle method.
//!
//! At iteration `t` BMRM solves (eq. 3)
//!
//! `min_w  max_{i≤t} { ⟨w, a_i⟩ + b_i } + λ‖w‖²`.
//!
//! Its Lagrangian dual over the cutting-plane weights `α ∈ Δ_t` (the
//! probability simplex) is the t-dimensional concave QP
//!
//! `max_α  −(1/4λ)‖Σ_i α_i a_i‖² + Σ_i α_i b_i`,   `w(α) = −(1/2λ) Σ_i α_i a_i`,
//!
//! (Teo et al., 2010, §3). `t` stays small (tens of planes — convergence
//! is `O(1/ελ)` independent of m), so we precompute the Gram matrix
//! `G_ij = ⟨a_i, a_j⟩` incrementally (one `O(t·n)` column per new plane)
//! and solve the dual with pairwise coordinate descent over the simplex,
//! replacing the paper's CVXOPT (DESIGN.md §6). Each sweep moves mass
//! between plane pairs along the exact 1-D optimum, so iterates stay
//! feasible and the dual objective is monotone.

/// Simplex-constrained dual QP state for a growing bundle.
pub struct BundleQp {
    lambda: f64,
    /// Gram matrix G[i][j] = ⟨a_i, a_j⟩, row-major, grows with the bundle.
    gram: Vec<Vec<f64>>,
    /// Plane offsets b_i.
    offsets: Vec<f64>,
    /// Current dual point (simplex).
    alpha: Vec<f64>,
}

impl BundleQp {
    pub fn new(lambda: f64) -> Self {
        assert!(lambda > 0.0);
        BundleQp { lambda, gram: Vec::new(), offsets: Vec::new(), alpha: Vec::new() }
    }

    pub fn n_planes(&self) -> usize {
        self.offsets.len()
    }

    pub fn alpha(&self) -> &[f64] {
        &self.alpha
    }

    /// Add a cutting plane given its offset `b` and its inner products
    /// with every existing plane plus itself (`col[i] = ⟨a_new, a_i⟩`,
    /// `col[t] = ⟨a_new, a_new⟩`). The caller owns the plane vectors; the
    /// QP only ever sees inner products, keeping it `O(t²)` regardless
    /// of the feature dimension.
    pub fn add_plane(&mut self, b: f64, col: Vec<f64>) {
        let t = self.n_planes();
        assert_eq!(col.len(), t + 1, "need inner products with all planes incl. self");
        for (i, row) in self.gram.iter_mut().enumerate() {
            row.push(col[i]);
        }
        self.gram.push(col);
        self.offsets.push(b);
        // Warm start: keep previous α, give the new plane zero weight —
        // unless this is the first plane.
        if t == 0 {
            self.alpha.push(1.0);
        } else {
            self.alpha.push(0.0);
        }
    }

    /// Dual objective `D(α) = −(1/4λ) αᵀGα + αᵀb` (to maximize).
    pub fn dual_objective(&self) -> f64 {
        let t = self.n_planes();
        let mut quad = 0.0;
        for i in 0..t {
            for j in 0..t {
                quad += self.alpha[i] * self.gram[i][j] * self.alpha[j];
            }
        }
        let lin: f64 = self.alpha.iter().zip(&self.offsets).map(|(a, b)| a * b).sum();
        -quad / (4.0 * self.lambda) + lin
    }

    /// Solve the dual to tolerance `tol` (max marginal improvement of a
    /// pairwise exchange) with at most `max_sweeps` full sweeps. Returns
    /// the achieved dual objective, which equals `min_w J_t(w)` at the
    /// exact optimum.
    pub fn solve(&mut self, tol: f64, max_sweeps: usize) -> f64 {
        let t = self.n_planes();
        if t == 0 {
            return 0.0;
        }
        if t == 1 {
            self.alpha[0] = 1.0;
            return self.dual_objective();
        }
        // g_i = ∂D/∂α_i = −(1/2λ)(Gα)_i + b_i ; maintained incrementally.
        let mut galpha = vec![0.0; t]; // (Gα)_i
        for i in 0..t {
            for j in 0..t {
                galpha[i] += self.gram[i][j] * self.alpha[j];
            }
        }
        let inv2l = 1.0 / (2.0 * self.lambda);
        for _sweep in 0..max_sweeps {
            // Pick the steepest feasible pair: u = argmax gradient,
            // v = argmin gradient among α_v > 0; move mass v → u.
            let grad = |i: usize, ga: &[f64], s: &Self| -> f64 { -inv2l * ga[i] + s.offsets[i] };
            let mut best_gain = 0.0f64;
            for _inner in 0..t {
                let mut u = 0;
                let mut gu = f64::NEG_INFINITY;
                let mut v = usize::MAX;
                let mut gv = f64::INFINITY;
                for i in 0..t {
                    let gi = grad(i, &galpha, self);
                    if gi > gu {
                        gu = gi;
                        u = i;
                    }
                    if self.alpha[i] > 0.0 && gi < gv {
                        gv = gi;
                        v = i;
                    }
                }
                if v == usize::MAX || u == v {
                    break;
                }
                let gap = gu - gv;
                if gap <= tol {
                    break;
                }
                // Exact line search for moving δ from v to u:
                // D(α + δ(e_u − e_v)) is quadratic in δ with curvature
                // κ = (G_uu − 2G_uv + G_vv)/(2λ) ≥ 0; optimum δ* = gap/κ,
                // clipped to δ ≤ α_v.
                let kappa = (self.gram[u][u] - 2.0 * self.gram[u][v] + self.gram[v][v]) * inv2l;
                let delta = if kappa <= 1e-300 {
                    self.alpha[v]
                } else {
                    (gap / kappa).min(self.alpha[v])
                };
                if delta <= 0.0 {
                    break;
                }
                self.alpha[u] += delta;
                self.alpha[v] -= delta;
                if self.alpha[v] < 1e-15 {
                    self.alpha[v] = 0.0;
                }
                for i in 0..t {
                    galpha[i] += delta * (self.gram[u][i] - self.gram[v][i]);
                }
                best_gain = best_gain.max(gap * delta);
            }
            if best_gain <= tol * 1e-3 {
                break;
            }
        }
        self.dual_objective()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Build a QP from explicit plane vectors; returns (qp, planes).
    fn qp_from_planes(lambda: f64, planes: &[(Vec<f64>, f64)]) -> BundleQp {
        let mut qp = BundleQp::new(lambda);
        for (t, (a, b)) in planes.iter().enumerate() {
            let mut col: Vec<f64> = (0..t)
                .map(|i| crate::linalg::ops::dot(a, &planes[i].0))
                .collect();
            col.push(crate::linalg::ops::dot(a, a));
            qp.add_plane(*b, col);
        }
        qp
    }

    fn primal_w(lambda: f64, planes: &[(Vec<f64>, f64)], alpha: &[f64]) -> Vec<f64> {
        let n = planes[0].0.len();
        let mut w = vec![0.0; n];
        for (k, (a, _)) in planes.iter().enumerate() {
            crate::linalg::ops::axpy(-alpha[k] / (2.0 * lambda), a, &mut w);
        }
        w
    }

    fn primal_obj(lambda: f64, planes: &[(Vec<f64>, f64)], w: &[f64]) -> f64 {
        let rt = planes
            .iter()
            .map(|(a, b)| crate::linalg::ops::dot(w, a) + b)
            .fold(f64::NEG_INFINITY, f64::max);
        rt + lambda * crate::linalg::ops::norm_sq(w)
    }

    #[test]
    fn single_plane_analytic() {
        // One plane: w* = −a/(2λ), J = −‖a‖²/(4λ) + b.
        let lambda = 0.5;
        let planes = vec![(vec![2.0, 0.0], 1.0)];
        let mut qp = qp_from_planes(lambda, &planes);
        let d = qp.solve(1e-12, 100);
        let expect = -4.0 / (4.0 * lambda) + 1.0;
        assert!((d - expect).abs() < 1e-10);
        assert_eq!(qp.alpha(), &[1.0]);
    }

    #[test]
    fn dual_matches_primal_grid_search_2planes() {
        let lambda = 0.3;
        let planes = vec![(vec![1.0, 2.0], 0.5), (vec![-2.0, 1.0], 0.2)];
        let mut qp = qp_from_planes(lambda, &planes);
        let d = qp.solve(1e-12, 1000);
        // Strong duality: D(α*) == min_w J_t(w). Check by fine grid on α.
        let mut best = f64::NEG_INFINITY;
        for k in 0..=10_000 {
            let a0 = k as f64 / 10_000.0;
            let alpha = [a0, 1.0 - a0];
            let mut quad = 0.0;
            let g = [
                [5.0f64, 0.0], // ⟨a0,a0⟩=5, ⟨a0,a1⟩=0
                [0.0, 5.0],
            ];
            for i in 0..2 {
                for j in 0..2 {
                    quad += alpha[i] * g[i][j] * alpha[j];
                }
            }
            let lin = alpha[0] * 0.5 + alpha[1] * 0.2;
            best = best.max(-quad / (4.0 * lambda) + lin);
        }
        assert!((d - best).abs() < 1e-6, "{d} vs {best}");
    }

    #[test]
    fn dual_equals_primal_randomized() {
        let mut rng = Rng::new(501);
        for _ in 0..10 {
            let lambda = rng.range(0.05, 2.0);
            let n = 2 + rng.below(6);
            let t = 2 + rng.below(6);
            let planes: Vec<(Vec<f64>, f64)> = (0..t)
                .map(|_| ((0..n).map(|_| rng.normal()).collect(), rng.normal()))
                .collect();
            let mut qp = qp_from_planes(lambda, &planes);
            let d = qp.solve(1e-12, 10_000);
            let w = primal_w(lambda, &planes, qp.alpha());
            let p = primal_obj(lambda, &planes, &w);
            // Weak duality always: d ≤ p. Near-equality at optimum.
            assert!(d <= p + 1e-8, "weak duality violated: {d} > {p}");
            assert!((p - d).abs() < 1e-5 * (1.0 + p.abs()), "gap {d} vs {p}");
        }
    }

    #[test]
    fn alpha_stays_on_simplex() {
        let mut rng = Rng::new(503);
        let planes: Vec<(Vec<f64>, f64)> =
            (0..8).map(|_| ((0..4).map(|_| rng.normal()).collect(), rng.normal())).collect();
        let mut qp = qp_from_planes(0.1, &planes);
        qp.solve(1e-10, 1000);
        let sum: f64 = qp.alpha().iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
        assert!(qp.alpha().iter().all(|&a| a >= 0.0));
    }

    #[test]
    fn warm_start_improves_monotonically() {
        let mut rng = Rng::new(505);
        let mut qp = BundleQp::new(0.2);
        let mut planes: Vec<(Vec<f64>, f64)> = Vec::new();
        let mut prev = f64::NEG_INFINITY;
        for _ in 0..6 {
            let a: Vec<f64> = (0..5).map(|_| rng.normal()).collect();
            let b = rng.normal();
            let mut col: Vec<f64> =
                planes.iter().map(|(ai, _)| crate::linalg::ops::dot(&a, ai)).collect();
            col.push(crate::linalg::ops::dot(&a, &a));
            planes.push((a, b));
            qp.add_plane(b, col);
            let d = qp.solve(1e-10, 1000);
            // Adding a plane raises the lower bound (dual is a max over a
            // larger feasible set after re-solve).
            assert!(d >= prev - 1e-9, "dual decreased: {prev} -> {d}");
            prev = d;
        }
    }
}
