//! Execution runtime: the persistent worker pool behind every parallel
//! region in the crate, plus the PJRT loader for the AOT-compiled XLA
//! artifacts.
//!
//! [`pool::WorkerPool`] is created once per trainer (sized by
//! `TrainConfig.n_threads`) and shared by the sharded oracle, the
//! parallel compute backend, and the parallel argsort — replacing the
//! per-call `std::thread::scope` spawns of PR 1. Since PR 4 it is a
//! work-stealing scheduler (deque per worker, LIFO local pop,
//! seeded randomized-victim stealing), and [`plan::WorkPlan`] packs
//! skewed per-item weights (query-group sizes) into the bounded-weight
//! task runs the scheduler balances. Scheduling freedom never touches a
//! result bit: every submitting region obeys the three bit-identity
//! invariants of `docs/DETERMINISM.md` (exact-integer decomposition,
//! disjoint task writes, serial fixed-order float reductions).
//!
//! `python/compile/aot.py` lowers the JAX/Pallas compute graphs (L1/L2)
//! once, at build time, to **HLO text** under `artifacts/` together with
//! a line-based `manifest.txt`. The `backend` module loads those
//! artifacts with `HloModuleProto::from_text_file`, compiles them on the
//! PJRT CPU client and executes them from the training hot path — Python
//! is never invoked at runtime. (Text, not serialized protos: jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; see
//! /opt/xla-example/README.md.)
//!
//! The PJRT execution path depends on the external `xla` bindings crate,
//! which the offline registry does not carry, so it is gated behind the
//! `xla` cargo feature. The artifact [`Manifest`] parser is plain Rust
//! and stays available unconditionally (the AOT pipeline and its tests
//! don't need a device runtime).

pub mod cache;
mod manifest;
pub mod plan;
pub mod pool;

pub use manifest::{Manifest, ManifestEntry};
pub use plan::WorkPlan;
pub use pool::{Task, WorkerPool};

#[cfg(feature = "xla")]
mod backend;

#[cfg(feature = "xla")]
pub use backend::{literal_1d, literal_2d, XlaBackend, XlaRuntime};
