//! Quickstart: train TreeRSVM on a synthetic ranking problem, evaluate,
//! save/reload the model.
//!
//!     cargo run --release --example quickstart

use ranksvm::coordinator::{evaluate, train, Method, RankModel, TrainConfig};
use ranksvm::data::synthetic;

fn main() -> anyhow::Result<()> {
    // 1. Data: 4000 dense examples with real-valued utility scores
    //    (r ≈ m — the regime where only TreeRSVM is linearithmic).
    let ds = synthetic::cadata_like(4000, 42);
    let (train_ds, test_ds) = ds.split(1000, 7);
    println!(
        "data: m={} n={} distinct-levels={} pairs≈{:.2e}",
        train_ds.len(),
        train_ds.dim(),
        train_ds.n_levels(),
        ranksvm::losses::count_comparable_pairs(&train_ds.y) as f64,
    );

    // 2. Train with the paper's defaults: ε = 1e-3, λ chosen for the data.
    let cfg = TrainConfig { method: Method::Tree, lambda: 0.1, ..Default::default() };
    let out = train(&train_ds, &cfg)?;
    println!(
        "trained: {} iterations, objective {:.6}, gap {:.2e}, {:.2}s total ({:.1} ms/oracle call)",
        out.iterations,
        out.objective,
        out.gap,
        out.train_secs,
        1e3 * out.avg_oracle_secs(),
    );

    // 3. Evaluate: pairwise ranking error (paper eq. 1) on held-out data.
    let err = evaluate(&out.model, &test_ds);
    println!("test pairwise ranking error: {err:.4}");
    assert!(err < 0.3, "expected a learnable problem (random = 0.5)");

    // 4. Persist and reload.
    let path = std::env::temp_dir().join("quickstart_model.txt");
    out.model.save(&path)?;
    let model = RankModel::load(&path)?;
    println!("model round-trip ok: dim={}", model.dim());

    // 5. Rank the first 5 test examples.
    let top = model.rank(&test_ds);
    println!("top-5 test examples by predicted utility: {:?}", &top[..5]);
    Ok(())
}
