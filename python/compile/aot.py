"""AOT pipeline: lower the L2 graphs once to HLO text + manifest.

Interchange format is HLO **text**, not serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the `xla`
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md and gen_hlo.py there).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Produces, for each configured shape:
  scores_{M}x{N}.hlo.txt      (x: f32[M,N], w: f32[N]) -> (f32[M],)
  grad_{M}x{N}.hlo.txt        (x: f32[M,N], c: f32[M]) -> (f32[N],)
  paircount_{M}.hlo.txt       (p, y, v: f32[M]) -> (f32[M], f32[M])
plus manifest.txt (one `op m n file` line per artifact — parsed by
rust/src/runtime/manifest.rs).
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Row-tile heights × feature widths for the matvec artifacts. N=8 covers
# cadata-like data exactly; N=64 is the padding bucket for wider dense
# sets. Taller tiles amortize per-execute overhead (the runtime prefers
# the tallest fitting tile); M=256 serves small tests.
MATVEC_SHAPES = [(256, 8), (1024, 8), (4096, 8), (1024, 64), (4096, 64)]
# Tile sizes for the pair-count artifact (PairRSVM baseline / AUC tile).
PAIRCOUNT_SIZES = [256, 1024]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_scores(m: int, n: int) -> str:
    x = jax.ShapeDtypeStruct((m, n), jnp.float32)
    w = jax.ShapeDtypeStruct((n,), jnp.float32)
    return to_hlo_text(jax.jit(model.scores_fn).lower(x, w))


def lower_grad(m: int, n: int) -> str:
    x = jax.ShapeDtypeStruct((m, n), jnp.float32)
    c = jax.ShapeDtypeStruct((m,), jnp.float32)
    return to_hlo_text(jax.jit(model.grad_fn).lower(x, c))


def lower_paircount(m: int) -> str:
    v = jax.ShapeDtypeStruct((m,), jnp.float32)
    return to_hlo_text(jax.jit(model.pair_count_fn).lower(v, v, v))


def build(out_dir: str, matvec_shapes=None, paircount_sizes=None) -> list[str]:
    """Lower everything into ``out_dir``; returns manifest lines."""
    matvec_shapes = matvec_shapes or MATVEC_SHAPES
    paircount_sizes = paircount_sizes or PAIRCOUNT_SIZES
    os.makedirs(out_dir, exist_ok=True)
    lines = ["# ranksvm AOT artifact manifest: op m n file"]

    for m, n in matvec_shapes:
        fname = f"scores_{m}x{n}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(lower_scores(m, n))
        lines.append(f"scores {m} {n} {fname}")
        print(f"lowered {fname}")

        fname = f"grad_{m}x{n}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(lower_grad(m, n))
        lines.append(f"grad {m} {n} {fname}")
        print(f"lowered {fname}")

    for m in paircount_sizes:
        fname = f"paircount_{m}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(lower_paircount(m))
        lines.append(f"paircount {m} 0 {fname}")
        print(f"lowered {fname}")

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote manifest with {len(lines) - 1} artifacts to {out_dir}")
    return lines


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    build(args.out_dir)


if __name__ == "__main__":
    main()
