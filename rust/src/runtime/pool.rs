//! Persistent work-stealing worker pool.
//!
//! PR 1 parallelized the subgradient oracle and the `O(ms)` matvecs with
//! `std::thread::scope`, which respawns every worker on every call; PR 2
//! replaced the per-call scopes with one persistent pool per trainer —
//! `N − 1` background threads created once (sized by
//! `TrainConfig.n_threads`) and reused by every parallel region until
//! the pool is dropped. That pool fed all workers from a single locked
//! `VecDeque`, which balances *queued* tasks but not *running* ones: a
//! batch of exactly `N` coarse tasks (one shard per worker, the PR 1–3
//! plan) is pinned to its initial assignment, so one oversized task — a
//! giant query group under Zipf-like group-size skew — serializes the
//! whole batch while the other workers idle.
//!
//! This revision makes the pool a **work-stealing scheduler**: one deque
//! per worker, tasks dealt as contiguous blocks at batch submit, each
//! worker popping its own deque LIFO (newest first — the block tail it
//! just received, still cache-warm) and, when empty, stealing FIFO from
//! a victim chosen by a seeded per-worker generator (oldest task — the
//! one its owner would reach last). Call sites now submit *more tasks
//! than workers* (per query-group run, per sorted-order chunk — see
//! [`super::plan::WorkPlan`] and `losses/sharded.rs`), so a worker that
//! finishes early drains the stragglers' queues instead of idling.
//! Model selection rides the same pool one level up: `ranksvm cv`
//! submits each (fold × λ-path) chain as one task
//! ([`crate::coordinator::modelsel`]), so a whole CV sweep is a single
//! batch over the shared dataset view. `run` is non-reentrant, which is
//! why those chains hand their inner oracles a 1-thread (inline) pool.
//!
//! **Scheduling-order freedom.** Stealing makes the execution order and
//! the task→thread assignment nondeterministic, but no result bit can
//! depend on either, by construction at every call site: each task
//! writes a disjoint slot (its own count/coefficient/output range) and
//! every floating-point reduction runs serially afterwards, in an order
//! fixed by the task *index*, not by completion time (see
//! `losses/sharded.rs` and `compute::ParallelBackend`). *Which* worker
//! runs a task — locally or stolen — therefore never touches a result
//! bit; the skew/determinism battery in `tests/scheduler.rs` pins this,
//! and `docs/DETERMINISM.md` writes the contract down as three
//! invariants every region submitting to this pool must satisfy.
//!
//! The API is scope-shaped: [`WorkerPool::run`] takes a batch of
//! closures that may borrow caller stack data (`'env`), executes them on
//! the pool plus the calling thread, and returns only once every closure
//! has finished — the same lifetime guarantee `std::thread::scope`
//! provides, with the threads themselves outliving the call.
//!
//! With one worker (`n_threads == 1`) the pool spawns no threads at all
//! and `run` degenerates to an in-place loop, keeping the serial path
//! free of synchronization; empty and singleton batches always take the
//! inline path.
//!
//! Per-batch executed/stolen counters are always on (see [`PoolStats`]):
//! relaxed atomics on the coarse task path cost nothing measurable, the
//! skew benchmark uses them to show the stealing actually engages on
//! imbalanced plans, and every increment is mirrored into the global
//! [`crate::obs::metrics`] registry so the serve daemon's `metrics` verb
//! and `train --trace` pool deltas see them too. The `pool-stats` cargo
//! feature remains as a deprecated no-op alias.

use crate::obs::metrics as obs_metrics;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A unit of pool work. The `'env` lifetime lets tasks borrow from the
/// submitting stack frame; [`WorkerPool::run`] erases it only for the
/// bounded interval during which it blocks on task completion.
pub type Task<'env> = Box<dyn FnOnce() + Send + 'env>;

/// Slots each worker reserves in its own deque when it starts, so the
/// backing allocation is first-touched by the thread that owns the
/// deque (on NUMA hosts the pages then sit on that worker's node rather
/// than the constructing thread's). 64 covers the largest adaptive
/// chunk plan; cache-sized plans beyond it grow in place on first use.
const DEQUE_SEED_CAPACITY: usize = 64;

type StaticTask = Box<dyn FnOnce() + Send + 'static>;

/// Cumulative scheduler counters (always on since the telemetry layer
/// landed; formerly behind the `pool-stats` feature). `executed` counts
/// tasks that went through the scheduler (inline fast-path tasks are
/// tallied separately), `stolen` the subset a worker took from another
/// worker's deque — the balance evidence the skew bench prints.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Batches dispatched through the deques (inline batches excluded).
    pub batches: u64,
    /// Tasks executed by the scheduler (local pops + steals).
    pub executed: u64,
    /// Tasks a worker stole from another worker's deque.
    pub stolen: u64,
    /// Tasks run on the submitting thread's inline fast path.
    pub inline_tasks: u64,
}

#[derive(Default)]
struct StatCounters {
    batches: std::sync::atomic::AtomicU64,
    executed: std::sync::atomic::AtomicU64,
    stolen: std::sync::atomic::AtomicU64,
    inline_tasks: std::sync::atomic::AtomicU64,
}

/// Batch control state guarded by one mutex: workers sleep on it between
/// batches, the submitter sleeps on it while stragglers finish.
struct Ctrl {
    /// Bumped once per dispatched batch; workers wake when it changes.
    epoch: u64,
    shutdown: bool,
}

struct PoolShared {
    /// One deque per worker; slot 0 belongs to the batch submitter.
    /// Local pops take the back (LIFO), steals take the front (FIFO).
    deques: Vec<Mutex<VecDeque<StaticTask>>>,
    ctrl: Mutex<Ctrl>,
    /// Workers wait here for the next batch epoch.
    work_cv: Condvar,
    /// The batch submitter waits here for the last task to finish.
    done_cv: Condvar,
    /// Tasks of the current batch not yet finished (queued or running).
    pending: AtomicUsize,
    /// Tasks of the current batch that panicked (payload dropped; the
    /// submitter re-raises a summary panic).
    panicked: AtomicUsize,
    /// Serializes whole batches: concurrent `run` calls from different
    /// threads queue up here instead of interleaving their tasks (and
    /// their panic accounting) in the deques.
    batch: Mutex<()>,
    stats: StatCounters,
}

impl PoolShared {
    /// Execute one task, keeping the completion accounting correct even
    /// when the task panics. `stolen` feeds the scheduler counters
    /// (per-pool and the global obs registry mirror).
    fn run_task(&self, task: StaticTask, stolen: bool) {
        self.stats.executed.fetch_add(1, Ordering::Relaxed);
        obs_metrics::POOL_TASKS.inc();
        if stolen {
            self.stats.stolen.fetch_add(1, Ordering::Relaxed);
            obs_metrics::POOL_STOLEN.inc();
        }
        let ok = catch_unwind(AssertUnwindSafe(task)).is_ok();
        if !ok {
            self.panicked.fetch_add(1, Ordering::SeqCst);
        }
        // SeqCst RMW: the submitter's acquire load of 0 synchronizes
        // with every decrement in the release sequence, so all task
        // writes are visible once `run` observes the batch drained.
        if self.pending.fetch_sub(1, Ordering::SeqCst) == 1 {
            // Take the lock before notifying so the submitter cannot
            // check `pending` and sleep between our decrement and
            // notification (the classic lost-wakeup interleaving).
            drop(self.ctrl.lock().unwrap());
            self.done_cv.notify_all();
        }
    }

    /// Run batch tasks until a full sweep finds no queued work: pop the
    /// own deque LIFO, then try stealing FIFO from victims starting at a
    /// seeded random offset. Tasks are only *removed* during a batch, so
    /// an empty sweep proves no queued task remains (running tasks are
    /// the submitter's `pending` wait, not ours).
    fn drain(&self, me: usize, rng: &mut StealRng) {
        let n = self.deques.len();
        'work: loop {
            // Bind the pop before the `if let`: an if-let scrutinee's
            // temporaries (the MutexGuard) live to the end of the body
            // in edition 2021, which would hold our own deque's lock
            // across the task and block every thief on it.
            let task = self.deques[me].lock().unwrap().pop_back();
            if let Some(task) = task {
                self.run_task(task, false);
                continue;
            }
            let start = rng.below(n);
            for k in 0..n {
                let victim = (start + k) % n;
                if victim == me {
                    continue;
                }
                let task = self.deques[victim].lock().unwrap().pop_front();
                if let Some(task) = task {
                    self.run_task(task, true);
                    continue 'work;
                }
            }
            return;
        }
    }
}

/// Small seeded generator for victim selection (splitmix64 core — the
/// same mixer `util::rng` uses to seed xoshiro). Each worker owns one,
/// seeded from its index, so victim order is reproducible run-to-run
/// even though it deliberately never influences a result bit.
struct StealRng(u64);

impl StealRng {
    fn new(worker: usize) -> Self {
        // Run the worker id through the mixer once: a linear seed
        // (id × constant) would put every worker on one phase-shifted
        // orbit — identical victim sequences, one step apart — making
        // simultaneously-idle workers contend on the same victim locks.
        let mut z = (worker as u64).wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        StealRng(z ^ (z >> 31))
    }

    fn below(&mut self, n: usize) -> usize {
        self.0 = self.0.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        (((z as u128) * (n as u128)) >> 64) as usize
    }
}

/// A persistent pool of `n_threads − 1` background workers plus the
/// calling thread, scheduling each batch over per-worker deques with
/// randomized-victim work stealing. Create once (per trainer / oracle /
/// backend), submit many batches; threads are joined on drop.
pub struct WorkerPool {
    n_threads: usize,
    shared: Arc<PoolShared>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Build a pool with `n_threads` total workers (the calling thread
    /// participates in every batch, so `n_threads − 1` threads are
    /// spawned; `0` and `1` both mean fully inline execution).
    pub fn new(n_threads: usize) -> Self {
        let n_threads = n_threads.max(1);
        let shared = Arc::new(PoolShared {
            deques: (0..n_threads).map(|_| Mutex::new(VecDeque::new())).collect(),
            ctrl: Mutex::new(Ctrl { epoch: 0, shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            pending: AtomicUsize::new(0),
            panicked: AtomicUsize::new(0),
            batch: Mutex::new(()),
            stats: StatCounters::default(),
        });
        // Slot 0 belongs to the batch-submitting thread — which is the
        // constructing thread's role — so its first touch happens here;
        // every spawned worker first-touches its own deque in
        // `worker_loop`.
        shared.deques[0].lock().unwrap().reserve(DEQUE_SEED_CAPACITY);
        let handles = (1..n_threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("ranksvm-pool-{i}"))
                    .spawn(move || worker_loop(&shared, i))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { n_threads, shared, handles }
    }

    /// Total workers, counting the calling thread.
    pub fn n_threads(&self) -> usize {
        self.n_threads
    }

    /// Snapshot of the cumulative scheduler counters.
    pub fn stats(&self) -> PoolStats {
        let s = &self.shared.stats;
        PoolStats {
            batches: s.batches.load(Ordering::Relaxed),
            executed: s.executed.load(Ordering::Relaxed),
            stolen: s.stolen.load(Ordering::Relaxed),
            inline_tasks: s.inline_tasks.load(Ordering::Relaxed),
        }
    }

    /// Reset the cumulative per-pool counters (e.g. between bench
    /// phases). The global obs registry mirror is monotonic and is
    /// deliberately *not* reset.
    pub fn reset_stats(&self) {
        let s = &self.shared.stats;
        s.batches.store(0, Ordering::Relaxed);
        s.executed.store(0, Ordering::Relaxed);
        s.stolen.store(0, Ordering::Relaxed);
        s.inline_tasks.store(0, Ordering::Relaxed);
    }

    /// Execute a batch of tasks, blocking until every task has finished
    /// (or panicked). Tasks may borrow from the caller's stack: the
    /// completion barrier below guarantees no task outlives `'env`.
    ///
    /// Tasks run concurrently on the pool threads and on the calling
    /// thread; submit tasks whose writes are disjoint. Submit *more*
    /// tasks than workers when their costs may be uneven — the stealing
    /// scheduler turns the surplus into balance. If any task panics, the
    /// remaining tasks still run to completion and `run` then panics
    /// (mirroring `std::thread::scope` semantics); the pool itself stays
    /// reusable.
    ///
    /// Reentrant submission (calling `run` from inside a task) is not
    /// supported and may deadlock.
    pub fn run<'env>(&self, tasks: Vec<Task<'env>>) {
        if tasks.is_empty() {
            return;
        }
        // Inline path: single worker, or a single task — nothing to
        // schedule. (Panics propagate directly, same net effect.)
        if self.handles.is_empty() || tasks.len() == 1 {
            self.shared.stats.inline_tasks.fetch_add(tasks.len() as u64, Ordering::Relaxed);
            obs_metrics::POOL_INLINE_TASKS.add(tasks.len() as u64);
            for task in tasks {
                task();
            }
            return;
        }
        // SAFETY: the only use of the erased tasks is inside this call:
        // they are either executed below on this thread or drained by
        // worker threads, and `run` does not return until
        // `pending == 0` — i.e. until every task (including panicked
        // ones, via `run_task`'s accounting) has completed. Borrows
        // captured at `'env` therefore strictly outlive every task
        // execution.
        let tasks: Vec<StaticTask> = tasks
            .into_iter()
            .map(|t| unsafe { std::mem::transmute::<Task<'env>, StaticTask>(t) })
            .collect();

        // One batch at a time: a second thread calling `run` blocks here
        // until the current batch fully drains, so batches can never
        // interleave tasks or clobber each other's panic accounting.
        // (A task calling `run` on its own pool would deadlock on this
        // lock — reentrancy is documented as unsupported.) The guard
        // protects no data, so a poisoned lock (possible only through a
        // panicking caller) is safe to recover.
        let batch = self.shared.batch.lock().unwrap_or_else(|e| e.into_inner());

        let n_tasks = tasks.len();
        let n_workers = self.n_threads;
        debug_assert!(
            self.shared.pending.load(Ordering::SeqCst) == 0,
            "WorkerPool::run is not reentrant"
        );
        self.shared.panicked.store(0, Ordering::SeqCst);
        // Publish the task count BEFORE any task becomes reachable: a
        // worker finishing a stale sweep may pop a freshly dealt task
        // the instant it lands in a deque.
        self.shared.pending.store(n_tasks, Ordering::SeqCst);
        self.shared.stats.batches.fetch_add(1, Ordering::Relaxed);
        obs_metrics::POOL_BATCHES.inc();

        // Deal contiguous blocks: worker w owns tasks
        // [w·T/N, (w+1)·T/N) — neighbouring tasks usually touch
        // neighbouring data, so the initial assignment is cache-friendly
        // and stealing only redistributes the imbalance.
        {
            let mut tasks = tasks.into_iter();
            for (w, deque) in self.shared.deques.iter().enumerate() {
                let lo = w * n_tasks / n_workers;
                let hi = (w + 1) * n_tasks / n_workers;
                if hi > lo {
                    deque.lock().unwrap().extend(tasks.by_ref().take(hi - lo));
                }
            }
            debug_assert!(tasks.next().is_none());
        }

        // Open the epoch and wake everyone.
        {
            let mut ctrl = self.shared.ctrl.lock().unwrap();
            ctrl.epoch = ctrl.epoch.wrapping_add(1);
        }
        self.shared.work_cv.notify_all();

        // The calling thread participates as worker 0 until no queued
        // work remains, then waits for stragglers running on pool
        // threads.
        let mut rng = StealRng::new(0);
        self.shared.drain(0, &mut rng);
        {
            let mut ctrl = self.shared.ctrl.lock().unwrap();
            while self.shared.pending.load(Ordering::SeqCst) != 0 {
                ctrl = self.shared.done_cv.wait(ctrl).unwrap();
            }
        }
        let panicked = self.shared.panicked.swap(0, Ordering::SeqCst);
        // Release the batch lock *before* re-raising so a panicked batch
        // does not poison it (the pool stays usable afterwards).
        drop(batch);
        if panicked > 0 {
            panic!("{panicked} worker-pool task(s) panicked");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.ctrl.lock().unwrap().shutdown = true;
        self.shared.work_cv.notify_all();
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

fn worker_loop(shared: &PoolShared, me: usize) {
    // First-touch this worker's scratch: reserving from the owning
    // thread allocates the deque's buffer on this worker's NUMA node
    // before any batch is dealt into it.
    shared.deques[me].lock().unwrap().reserve(DEQUE_SEED_CAPACITY);
    let mut rng = StealRng::new(me);
    let mut seen_epoch = 0u64;
    loop {
        {
            let mut ctrl = shared.ctrl.lock().unwrap();
            loop {
                if ctrl.shutdown {
                    return;
                }
                if ctrl.epoch != seen_epoch {
                    seen_epoch = ctrl.epoch;
                    break;
                }
                ctrl = shared.work_cv.wait(ctrl).unwrap();
            }
        }
        shared.drain(me, &mut rng);
        // A drained sweep can race the next batch's deal: harmless — the
        // tasks it grabs belong to the already-published `pending`, and
        // the epoch check above re-runs the sweep after the wakeup.
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn boxed<'env>(f: impl FnOnce() + Send + 'env) -> Task<'env> {
        Box::new(f)
    }

    #[test]
    fn runs_all_tasks_with_borrowed_state() {
        let pool = WorkerPool::new(4);
        let mut out = vec![0usize; 64];
        {
            let mut tasks: Vec<Task> = Vec::new();
            let mut rest: &mut [usize] = &mut out;
            let mut base = 0;
            for _ in 0..8 {
                let (head, tail) = { rest }.split_at_mut(8);
                let lo = base;
                tasks.push(boxed(move || {
                    for (k, slot) in head.iter_mut().enumerate() {
                        *slot = lo + k;
                    }
                }));
                rest = tail;
                base += 8;
            }
            pool.run(tasks);
        }
        assert_eq!(out, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn pool_is_reusable_across_many_batches() {
        let pool = WorkerPool::new(3);
        let counter = AtomicUsize::new(0);
        for _ in 0..200 {
            let tasks: Vec<Task> = (0..5)
                .map(|_| {
                    boxed(|| {
                        counter.fetch_add(1, Ordering::Relaxed);
                    })
                })
                .collect();
            pool.run(tasks);
        }
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }

    /// Force a steal structurally: the caller's first LIFO pop (the
    /// *back* of its dealt block) blocks until the *front* of that same
    /// block has executed — which can only happen on another worker,
    /// via a steal. A broken scheduler times out instead of passing.
    #[test]
    fn blocked_owner_tasks_are_stolen_by_idle_workers() {
        use std::sync::atomic::AtomicBool;
        let pool = WorkerPool::new(4);
        let stealable_ran = AtomicBool::new(false);
        let mut tasks: Vec<Task> = Vec::new();
        // Dealt to worker 0 (the caller): block [0, 2). Caller pops the
        // back first, so the spinner runs on the caller while the
        // stealable task sits at the deque front.
        tasks.push(boxed(|| {
            stealable_ran.store(true, Ordering::SeqCst);
        }));
        tasks.push(boxed(|| {
            let t0 = std::time::Instant::now();
            while !stealable_ran.load(Ordering::SeqCst) {
                assert!(t0.elapsed().as_secs() < 10, "steal never happened");
                std::hint::spin_loop();
            }
        }));
        // Trivial filler for workers 1–3's blocks.
        for _ in 0..6 {
            tasks.push(boxed(|| {}));
        }
        pool.run(tasks);
        assert!(stealable_ran.load(Ordering::SeqCst));
    }

    #[test]
    fn single_thread_pool_spawns_nothing_and_runs_inline() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.n_threads(), 1);
        let tid = std::thread::current().id();
        let mut seen = Vec::new();
        {
            let seen_ref = &mut seen;
            pool.run(vec![boxed(move || seen_ref.push(std::thread::current().id()))]);
        }
        assert_eq!(seen, vec![tid]);
    }

    #[test]
    fn zero_means_one() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.n_threads(), 1);
        pool.run(vec![boxed(|| {})]);
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let pool = WorkerPool::new(4);
        pool.run(Vec::new());
    }

    #[test]
    fn singleton_batch_runs_on_the_calling_thread() {
        let pool = WorkerPool::new(4);
        let tid = std::thread::current().id();
        let mut seen = None;
        {
            let seen_ref = &mut seen;
            pool.run(vec![boxed(move || *seen_ref = Some(std::thread::current().id()))]);
        }
        assert_eq!(seen, Some(tid));
    }

    #[test]
    fn task_panic_propagates_after_batch_completes() {
        let pool = WorkerPool::new(4);
        let finished = AtomicUsize::new(0);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let tasks: Vec<Task> = (0..8)
                .map(|i| {
                    let finished = &finished;
                    boxed(move || {
                        if i == 3 {
                            panic!("boom");
                        }
                        finished.fetch_add(1, Ordering::Relaxed);
                    })
                })
                .collect();
            pool.run(tasks);
        }));
        assert!(result.is_err());
        // Every non-panicking task still ran (the barrier held).
        assert_eq!(finished.load(Ordering::Relaxed), 7);
        // The pool stays usable after a panicked batch.
        let counter = AtomicUsize::new(0);
        pool.run(
            (0..4)
                .map(|_| {
                    let counter = &counter;
                    boxed(move || {
                        counter.fetch_add(1, Ordering::Relaxed);
                    })
                })
                .collect(),
        );
        assert_eq!(counter.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = WorkerPool::new(8);
        let counter = AtomicUsize::new(0);
        pool.run(
            (0..32)
                .map(|_| {
                    let counter = &counter;
                    boxed(move || {
                        counter.fetch_add(1, Ordering::Relaxed);
                    })
                })
                .collect(),
        );
        assert_eq!(counter.load(Ordering::Relaxed), 32);
        drop(pool); // must not hang
    }

    #[test]
    fn stats_count_batches_and_engage_stealing_on_skew() {
        use std::sync::atomic::AtomicBool;
        let pool = WorkerPool::new(4);
        pool.reset_stats();
        // Inline paths are tallied separately.
        pool.run(vec![boxed(|| {})]);
        assert_eq!(pool.stats().inline_tasks, 1);
        assert_eq!(pool.stats().batches, 0);
        // Same forced-steal construction as
        // `blocked_owner_tasks_are_stolen_by_idle_workers`: the caller
        // blocks on its block's back until the front has been stolen.
        let stealable_ran = AtomicBool::new(false);
        let mut tasks: Vec<Task> = Vec::new();
        tasks.push(boxed(|| {
            stealable_ran.store(true, Ordering::SeqCst);
        }));
        tasks.push(boxed(|| {
            let t0 = std::time::Instant::now();
            while !stealable_ran.load(Ordering::SeqCst) {
                assert!(t0.elapsed().as_secs() < 10, "steal never happened");
                std::hint::spin_loop();
            }
        }));
        for _ in 0..6 {
            tasks.push(boxed(|| {}));
        }
        pool.run(tasks);
        let s = pool.stats();
        assert_eq!(s.batches, 1);
        assert_eq!(s.executed, 8);
        assert!(s.stolen > 0, "no steals on a blocked-owner batch: {s:?}");
    }
}
