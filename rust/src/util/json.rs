//! Minimal JSON emission and parsing for metric logs, run traces, and
//! bench reports.
//!
//! The offline crate set ships no `serde`/`serde_json`; benches and the
//! trainer emit machine-readable records through this tiny writer
//! instead, and `ranksvm report` reads trace JSONL back through
//! [`Json::parse`]. Only what we need: objects, arrays, strings,
//! numbers, bools.

use anyhow::{ensure, Result};
use std::fmt::Write as _;

/// A JSON value builder producing compact single-line output.
#[derive(Clone, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Convenience: object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Convenience: array of f64.
    pub fn nums(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Parse a JSON document (recursive descent over the full grammar
    /// this writer emits, plus whitespace and `\u` escapes).
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        ensure!(p.i == p.b.len(), "trailing garbage at byte {}", p.i);
        Ok(v)
    }

    /// Object field lookup (first match; `None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric value as f64 (`Int` widens losslessly for our ranges).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            Json::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact single-line serialization (also powers
/// `Json::to_string()` via the blanket `ToString`).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

/// Byte-cursor recursive-descent parser.
struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        ensure!(self.peek() == Some(c), "expected {:?} at byte {}", c as char, self.i);
        self.i += 1;
        Ok(())
    }

    fn eat_lit(&mut self, lit: &str, v: Json) -> Result<Json> {
        ensure!(self.b[self.i..].starts_with(lit.as_bytes()), "bad literal at byte {}", self.i);
        self.i += lit.len();
        Ok(v)
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.eat_lit("null", Json::Null),
            Some(b't') => self.eat_lit("true", Json::Bool(true)),
            Some(b'f') => self.eat_lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i),
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        let mut float = false;
        while let Some(c) = self.peek() {
            match c {
                b'0'..=b'9' | b'-' | b'+' => self.i += 1,
                b'.' | b'e' | b'E' => {
                    float = true;
                    self.i += 1;
                }
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).expect("ascii number");
        if !float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        let x: f64 = text.parse().map_err(|e| anyhow::anyhow!("bad number {text:?}: {e}"))?;
        Ok(Json::Num(x))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = match self.peek() {
                Some(c) => c,
                None => anyhow::bail!("unterminated string at byte {}", self.i),
            };
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek();
                    self.i += 1;
                    match e {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let cp = self.hex4()?;
                            // Combine a UTF-16 surrogate pair if one
                            // follows; lone surrogates are an error.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                ensure!(
                                    (0xDC00..0xE000).contains(&lo),
                                    "bad low surrogate at byte {}",
                                    self.i
                                );
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(c)
                            } else {
                                char::from_u32(cp)
                            };
                            match ch {
                                Some(ch) => out.push(ch),
                                None => anyhow::bail!("bad \\u escape at byte {}", self.i),
                            }
                        }
                        other => {
                            let shown = other.map(|c| c as char);
                            anyhow::bail!("bad escape {:?} at byte {}", shown, self.i)
                        }
                    }
                }
                c if c < 0x80 => out.push(c as char),
                _ => {
                    // Multi-byte UTF-8: re-decode from the byte before.
                    let rest = std::str::from_utf8(&self.b[self.i - 1..])
                        .map_err(|_| anyhow::anyhow!("bad utf-8 at byte {}", self.i - 1))?;
                    let ch = rest.chars().next().expect("non-empty");
                    out.push(ch);
                    self.i += ch.len_utf8() - 1;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        ensure!(self.i + 4 <= self.b.len(), "truncated \\u escape at byte {}", self.i);
        let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| anyhow::anyhow!("bad \\u escape at byte {}", self.i))?;
        let cp = u32::from_str_radix(hex, 16)
            .map_err(|_| anyhow::anyhow!("bad \\u escape at byte {}", self.i))?;
        self.i += 4;
        Ok(cp)
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => anyhow::bail!("expected ',' or ']' at byte {}", self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut kvs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(kvs));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            kvs.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(kvs));
                }
                _ => anyhow::bail!("expected ',' or '}}' at byte {}", self.i),
            }
        }
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Int(x as i64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_object() {
        let j = Json::obj(vec![
            ("method", "tree".into()),
            ("m", 1000usize.into()),
            ("loss", 0.25f64.into()),
            ("ok", true.into()),
        ]);
        assert_eq!(j.to_string(), r#"{"method":"tree","m":1000,"loss":0.25,"ok":true}"#);
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn nested_arrays() {
        let j = Json::Arr(vec![Json::nums(&[1.0, 2.5]), Json::Null]);
        assert_eq!(j.to_string(), "[[1,2.5],null]");
    }

    #[test]
    fn parse_roundtrips_writer_output() {
        let j = Json::obj(vec![
            ("method", "tree".into()),
            ("m", 1000usize.into()),
            ("loss", 0.25f64.into()),
            ("ok", true.into()),
            ("none", Json::Null),
            ("xs", Json::nums(&[1.0, -2.5e-3])),
            ("nested", Json::obj(vec![("s", "a\"b\\c\nd".into())])),
        ]);
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.to_string(), text);
        assert_eq!(back.get("method").and_then(Json::as_str), Some("tree"));
        assert_eq!(back.get("m").and_then(Json::as_i64), Some(1000));
        assert_eq!(back.get("loss").and_then(Json::as_f64), Some(0.25));
        assert_eq!(back.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(back.get("xs").and_then(Json::as_arr).map(<[Json]>::len), Some(2));
        let s = back.get("nested").and_then(|n| n.get("s")).and_then(Json::as_str);
        assert_eq!(s, Some("a\"b\\c\nd"));
    }

    #[test]
    fn parse_accepts_whitespace_and_escapes() {
        let j = Json::parse(" { \"a\" : [ 1 , 2.5 , \"\\u00e9\\u2603\" ] } ").unwrap();
        let xs = j.get("a").and_then(Json::as_arr).unwrap();
        assert_eq!(xs[0].as_i64(), Some(1));
        assert_eq!(xs[2].as_str(), Some("é☃"));
        // Surrogate pair (🦀 U+1F980).
        let crab = Json::parse("\"\\ud83e\\udd80\"").unwrap();
        assert_eq!(crab.as_str(), Some("🦀"));
        // Raw multi-byte UTF-8 passes through.
        let raw = Json::parse("\"héllo\"").unwrap();
        assert_eq!(raw.as_str(), Some("héllo"));
    }

    #[test]
    fn parse_rejects_malformed_documents() {
        for bad in ["", "{", "[1,", "{\"a\":}", "tru", "1 2", "\"\\q\"", "\"\\ud800\"", "nan"] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }
}
