"""Pure-jnp correctness oracles for the Pallas kernels (L1).

Every Pallas kernel in this package is validated against these
references by ``python/tests/`` (hypothesis sweeps) before the AOT
artifacts are built. They are also what the kernels lower to
numerically: the rust integration test executes the AOT artifacts and
compares against an independent Rust implementation of the same math.
"""

import jax.numpy as jnp


def scores_ref(x, w):
    """p = X @ w for a dense row-major tile.

    Args:
      x: (m, n) f32 feature tile.
      w: (n,) f32 weight vector.
    Returns:
      (m,) f32 predicted scores.
    """
    return x @ w


def grad_ref(x, coeffs):
    """a = X^T @ coeffs — the subgradient assembly (Lemma 2).

    Args:
      x: (m, n) f32 feature tile.
      coeffs: (m,) f32 per-example gradient coefficients (c - d)/N.
    Returns:
      (n,) f32 subgradient contribution of this tile.
    """
    return x.T @ coeffs


def pair_count_ref(p, y, valid):
    """Frequencies c, d of eqs. (5)-(6) by explicit O(m^2) broadcasting.

    The baseline PairRSVM computation expressed as masked outer
    comparisons — the reference for the tiled ``pair_count`` kernel.

    Args:
      p: (m,) f32 predicted scores.
      y: (m,) f32 utility labels.
      valid: (m,) f32 {0,1} mask (0 marks padding rows).
    Returns:
      (c, d): two (m,) f32 vectors of margin-violation counts.
    """
    pi = p[:, None]
    pj = p[None, :]
    yi = y[:, None]
    yj = y[None, :]
    vv = valid[:, None] * valid[None, :]
    # Canonical hinge predicate 1 + p_low - p_high > 0 (same float
    # expression as every rust oracle — see losses/tree.rs).
    c = jnp.sum(jnp.where((yj > yi) & (1.0 + pi - pj > 0.0), vv, 0.0), axis=1)
    d = jnp.sum(jnp.where((yj < yi) & (1.0 + pj - pi > 0.0), vv, 0.0), axis=1)
    return c, d


def hinge_loss_ref(p, y):
    """Average pairwise hinge loss, eq. (4) — direct O(m^2) definition."""
    diff = 1.0 + p[:, None] - p[None, :]
    comparable = y[:, None] < y[None, :]
    n = jnp.sum(comparable)
    loss = jnp.sum(jnp.where(comparable, jnp.maximum(diff, 0.0), 0.0))
    return jnp.where(n > 0, loss / n, 0.0)
