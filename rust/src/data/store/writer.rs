//! Streaming libsvm → pallas-store converter.
//!
//! Single pass over the text in bounded memory: per-example state is
//! `O(m)` (labels, qids, row offsets — the arrays the header needs
//! before any section can be placed), but the matrix payload — `nnz`
//! column indices and values, the part that actually dominates at scale
//! — is never resident. Feature entries stream through two fixed-budget
//! spill buffers into temporary files as they are parsed, then are
//! copied chunk-by-chunk into their final sections once the counts are
//! known. `ConvertStats::max_buffered_bytes` reports the exact high-water
//! mark of the spill buffers, so tests can assert the bound instead of
//! hoping RSS behaves.

use super::format::{
    Checksum, Header, FLAG_HAS_QID, HEADER_LEN, N_SECTIONS, SEC_GEX, SEC_GOFF, SEC_GPAIRS,
    SEC_INDICES, SEC_INDPTR, SEC_QID, SEC_VALUES, SEC_Y,
};
use crate::data::libsvm::{parse_line, Example, RowAccumulator};
use crate::losses::{count_comparable_pairs, GroupIndex};
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

/// Converter knobs.
#[derive(Clone, Copy, Debug)]
pub struct ConvertOptions {
    /// Combined budget (bytes) for the two feature spill buffers — the
    /// chunk size of the chunked ingest. The converter's transient
    /// matrix memory never exceeds this (plus one buffer's worth of
    /// copy scratch during assembly).
    pub chunk_bytes: usize,
}

impl Default for ConvertOptions {
    fn default() -> Self {
        // 8 MiB moves ~350k sparse rows per flush; small enough that a
        // laptop never notices, big enough that syscalls don't dominate.
        ConvertOptions { chunk_bytes: 8 << 20 }
    }
}

/// What the converter did — printed as JSON by `ranksvm convert`.
#[derive(Clone, Copy, Debug)]
pub struct ConvertStats {
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    pub n_groups: usize,
    /// Comparable pairs of the training objective (global count, or the
    /// per-group sum for qid data).
    pub n_pairs: u64,
    /// Final store size in bytes.
    pub out_bytes: u64,
    /// High-water mark of the feature spill buffers (≤ `chunk_bytes`
    /// plus one entry of slack) — the "bounded memory" guarantee, made
    /// measurable.
    pub max_buffered_bytes: usize,
}

/// A byte sink that spills to a temp file whenever the in-memory buffer
/// reaches its budget.
struct SpillBuf {
    file: std::fs::File,
    path: PathBuf,
    buf: Vec<u8>,
    cap: usize,
    spilled: u64,
}

impl SpillBuf {
    fn create(path: PathBuf, cap: usize) -> Result<Self> {
        // Read + write: the same handle is rewound and read back during
        // assembly (a write-only fd would EBADF on that read).
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .with_context(|| format!("create spill file {}", path.display()))?;
        Ok(SpillBuf { file, path, buf: Vec::new(), cap: cap.max(64), spilled: 0 })
    }

    fn push(&mut self, bytes: &[u8]) -> Result<()> {
        self.buf.extend_from_slice(bytes);
        if self.buf.len() >= self.cap {
            self.flush()?;
        }
        Ok(())
    }

    fn flush(&mut self) -> Result<()> {
        if !self.buf.is_empty() {
            self.file.write_all(&self.buf).context("writing spill file")?;
            self.spilled += self.buf.len() as u64;
            self.buf.clear();
        }
        Ok(())
    }

    /// Total bytes pushed so far (spilled + still buffered).
    fn len(&self) -> u64 {
        self.spilled + self.buf.len() as u64
    }

    /// Reopen for reading from the start (after a final flush).
    fn into_reader(mut self) -> Result<(std::fs::File, PathBuf)> {
        self.flush()?;
        self.file.seek(SeekFrom::Start(0)).context("rewinding spill file")?;
        Ok((self.file, self.path))
    }
}

/// Checksummed, position-tracking section writer for the output file.
struct SectionWriter {
    out: std::io::BufWriter<std::fs::File>,
    pos: u64,
    sum: Checksum,
}

impl SectionWriter {
    fn write(&mut self, bytes: &[u8]) -> Result<()> {
        self.out.write_all(bytes).context("writing store")?;
        self.sum.update(bytes);
        self.pos += bytes.len() as u64;
        Ok(())
    }

    /// Zero-pad to the next 8-byte boundary (padding is checksummed like
    /// any other payload byte).
    fn pad8(&mut self) -> Result<()> {
        let rem = (self.pos % 8) as usize;
        if rem != 0 {
            self.write(&[0u8; 8][..8 - rem])?;
        }
        Ok(())
    }

    /// Buffered u64 stream write (little-endian).
    fn write_u64s<I: IntoIterator<Item = u64>>(&mut self, items: I) -> Result<()> {
        let mut chunk = [0u8; 8 * 512];
        let mut fill = 0usize;
        for v in items {
            chunk[fill..fill + 8].copy_from_slice(&v.to_le_bytes());
            fill += 8;
            if fill == chunk.len() {
                self.write(&chunk)?;
                fill = 0;
            }
        }
        if fill > 0 {
            self.write(&chunk[..fill])?;
        }
        Ok(())
    }
}

/// Convert a libsvm text file to a pallas store. One pass, chunked,
/// bounded memory; the output is byte-for-byte deterministic in the
/// input (and independent of `chunk_bytes`, which only controls flush
/// cadence — a test pins that).
pub fn convert_libsvm(
    input: impl AsRef<Path>,
    output: impl AsRef<Path>,
    opts: &ConvertOptions,
) -> Result<ConvertStats> {
    let input = input.as_ref();
    let output = output.as_ref();
    if input == output
        || (output.exists()
            && input
                .canonicalize()
                .ok()
                .zip(output.canonicalize().ok())
                .is_some_and(|(a, b)| a == b))
    {
        bail!("refusing to overwrite the input: output {} is the input file", output.display());
    }
    let ind_tmp = output.with_extension("pstore.indices.tmp");
    let val_tmp = output.with_extension("pstore.values.tmp");
    let mut output_created = false;
    let result = convert_impl(input, output, opts, &ind_tmp, &val_tmp, &mut output_created);
    if result.is_err() {
        // A failed conversion must leave neither a corrupt half-written
        // store (a zeroed header would autodetect as libsvm text and
        // fail confusingly downstream) nor spill litter behind — but
        // never delete an output this run didn't create (a parse
        // failure must not destroy a pre-existing good store).
        if output_created {
            std::fs::remove_file(output).ok();
        }
        std::fs::remove_file(&ind_tmp).ok();
        std::fs::remove_file(&val_tmp).ok();
    }
    result
}

fn convert_impl(
    input: &Path,
    output: &Path,
    opts: &ConvertOptions,
    ind_tmp: &Path,
    val_tmp: &Path,
    output_created: &mut bool,
) -> Result<ConvertStats> {
    let name = input.display().to_string();
    let reader = BufReader::new(
        std::fs::File::open(input).with_context(|| format!("open {}", input.display()))?,
    );

    // --- Pass: parse lines, stream features to spill files. The
    // per-row policy (zero skip, feature-space widening, qid defaults)
    // lives in the shared RowAccumulator, so this path cannot drift
    // from libsvm::parse. ---
    let spill_cap = (opts.chunk_bytes / 2).max(64);
    let mut ind_spill = SpillBuf::create(ind_tmp.to_path_buf(), spill_cap)?;
    let mut val_spill = SpillBuf::create(val_tmp.to_path_buf(), spill_cap)?;
    let mut acc = RowAccumulator::default();
    let mut indptr: Vec<u64> = vec![0];
    let mut nnz = 0u64;
    let mut max_buffered = 0usize;
    let mut ex = Example::default();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        if !parse_line(&line, &name, lineno + 1, &mut ex)? {
            continue;
        }
        acc.push(&ex, |idx, val| {
            let col = u32::try_from(idx - 1)
                .map_err(|_| anyhow::anyhow!("{name}: feature index {idx} exceeds u32"))?;
            ind_spill.push(&col.to_le_bytes())?;
            val_spill.push(&val.to_le_bytes())?;
            nnz += 1;
            Ok(())
        })?;
        max_buffered = max_buffered.max(ind_spill.buf.len() + val_spill.buf.len());
        indptr.push(nnz);
    }
    let any_qid = acc.any_qid;
    let max_col = acc.max_col;
    let (y, qid, _) = acc.into_qid();
    let rows = y.len();

    // --- Group index + pair counts (O(m) state, same code as the text
    // path so the loaded values are bit-identical). ---
    let gindex = qid.as_ref().map(|q| GroupIndex::build(q, &y));
    let n_pairs = match &gindex {
        Some(gi) => {
            let mut total = 0u64;
            for g in 0..gi.n_groups() {
                total += gi.group_pairs(g);
            }
            total
        }
        None => count_comparable_pairs(&y),
    };
    let n_groups = gindex.as_ref().map(|g| g.n_groups()).unwrap_or(0);

    // --- Assemble the output file. ---
    let mut header = Header {
        rows: rows as u64,
        cols: max_col as u64,
        nnz,
        flags: if any_qid { FLAG_HAS_QID } else { 0 },
        n_groups: n_groups as u64,
        n_pairs,
        checksum: 0,
        offsets: [0; N_SECTIONS],
    };
    let out_file = std::fs::File::create(output)
        .with_context(|| format!("create {}", output.display()))?;
    *output_created = true;
    let mut w = SectionWriter {
        out: std::io::BufWriter::new(out_file),
        pos: HEADER_LEN as u64,
        sum: Checksum::new(),
    };
    // Header placeholder; rewritten with the checksum at the end.
    w.out.write_all(&[0u8; HEADER_LEN]).context("writing store header")?;

    header.offsets[SEC_INDPTR] = w.pos;
    w.write_u64s(indptr.iter().copied())?;
    drop(indptr);

    w.pad8()?;
    header.offsets[SEC_INDICES] = w.pos;
    copy_spill(&mut w, ind_spill, opts.chunk_bytes)?;
    w.pad8()?;
    header.offsets[SEC_VALUES] = w.pos;
    copy_spill(&mut w, val_spill, opts.chunk_bytes)?;

    w.pad8()?;
    header.offsets[SEC_Y] = w.pos;
    w.write_u64s(y.iter().map(|v| v.to_bits()))?;

    header.offsets[SEC_QID] = w.pos;
    if let Some(q) = &qid {
        w.write_u64s(q.iter().copied())?;
    }
    header.offsets[SEC_GOFF] = w.pos;
    if let Some(gi) = &gindex {
        let (offsets, _, _) = gi.as_parts();
        w.write_u64s(offsets.iter().map(|&v| v as u64))?;
    }
    header.offsets[SEC_GEX] = w.pos;
    if let Some(gi) = &gindex {
        let (_, examples, _) = gi.as_parts();
        w.write_u64s(examples.iter().map(|&v| v as u64))?;
    }
    header.offsets[SEC_GPAIRS] = w.pos;
    if let Some(gi) = &gindex {
        let (_, _, pairs) = gi.as_parts();
        w.write_u64s(pairs.iter().copied())?;
    }

    let out_bytes = w.pos;
    // Fold the final header (checksum slot excluded) into the payload
    // stream — full-file coverage, so any later byte flip is caught.
    let mut sum = w.sum;
    sum.update_header(&header.encode());
    header.checksum = sum.finish();
    let mut out = w.out.into_inner().context("flushing store")?;
    out.seek(SeekFrom::Start(0)).context("rewinding store")?;
    out.write_all(&header.encode()).context("writing store header")?;
    out.sync_all().ok();
    drop(out);

    Ok(ConvertStats {
        rows,
        cols: max_col,
        nnz: nnz as usize,
        n_groups,
        n_pairs,
        out_bytes,
        max_buffered_bytes: max_buffered,
    })
}

/// Copy a finalized spill file into the output in `chunk_bytes`-bounded
/// reads, then delete it. Verifies the byte count written during the
/// parse pass survived the round trip.
fn copy_spill(w: &mut SectionWriter, spill: SpillBuf, chunk_bytes: usize) -> Result<()> {
    let expect = spill.len();
    let (mut file, path) = spill.into_reader()?;
    let mut buf = vec![0u8; chunk_bytes.clamp(4096, 8 << 20)];
    let mut copied = 0u64;
    loop {
        let n = file.read(&mut buf).context("reading spill file")?;
        if n == 0 {
            break;
        }
        w.write(&buf[..n])?;
        copied += n as u64;
    }
    drop(file);
    std::fs::remove_file(&path).ok();
    if copied != expect {
        bail!("spill file {} changed size during conversion ({copied} vs {expect})", path.display());
    }
    Ok(())
}
