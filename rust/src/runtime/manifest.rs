//! Line-based artifact manifest written by `python/compile/aot.py`.
//!
//! Format (one artifact per line, `#` comments):
//!
//! ```text
//! <op> <m> <n> <file>
//! scores 1024 8 scores_1024x8.hlo.txt
//! grad   1024 8 grad_1024x8.hlo.txt
//! paircount 512 0 paircount_512.hlo.txt
//! ```
//!
//! `m` is the row-tile height; `n` the feature width (0 when not
//! applicable). A plain-text format instead of JSON keeps the build-time
//! contract trivially greppable and diff-able (and the offline crate set
//! has no serde — DESIGN.md §6).

use anyhow::{bail, Context, Result};
use std::path::Path;

/// One artifact record.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ManifestEntry {
    pub op: String,
    /// Row-tile height.
    pub m: usize,
    /// Feature width (0 = n/a).
    pub n: usize,
    /// File name relative to the artifact directory.
    pub file: String,
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let parts: Vec<&str> = line.split_ascii_whitespace().collect();
            if parts.len() != 4 {
                bail!("manifest line {}: expected `op m n file`, got {line:?}", lineno + 1);
            }
            entries.push(ManifestEntry {
                op: parts[0].to_string(),
                m: parts[1].parse().with_context(|| format!("line {}: bad m", lineno + 1))?,
                n: parts[2].parse().with_context(|| format!("line {}: bad n", lineno + 1))?,
                file: parts[3].to_string(),
            });
        }
        Ok(Manifest { entries })
    }

    /// All entries for an op.
    pub fn for_op<'a>(&'a self, op: &'a str) -> impl Iterator<Item = &'a ManifestEntry> + 'a {
        self.entries.iter().filter(move |e| e.op == op)
    }

    /// Entry of `op` whose feature width fits `n` with the least padding,
    /// preferring the tallest row tile among equal widths (fewer
    /// executions per matvec — §Perf).
    pub fn best_for(&self, op: &str, n: usize) -> Option<&ManifestEntry> {
        self.entries
            .iter()
            .filter(|e| e.op == op && e.n >= n)
            .min_by_key(|e| (e.n, usize::MAX - e.m))
    }

    /// Entry of `op` with the largest row tile (for big batches).
    pub fn largest_tile(&self, op: &str) -> Option<&ManifestEntry> {
        self.entries.iter().filter(|e| e.op == op).max_by_key(|e| e.m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# artifact manifest
scores 1024 8 scores_1024x8.hlo.txt
scores 1024 64 scores_1024x64.hlo.txt
grad 1024 8 grad_1024x8.hlo.txt
paircount 512 0 paircount_512.hlo.txt
";

    #[test]
    fn parses_and_filters() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 4);
        assert_eq!(m.for_op("scores").count(), 2);
        assert_eq!(m.best_for("scores", 8).unwrap().n, 8);
        assert_eq!(m.best_for("scores", 9).unwrap().n, 64);
        assert_eq!(m.best_for("scores", 65), None);
        assert_eq!(m.largest_tile("paircount").unwrap().m, 512);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("scores 1024 8\n").is_err());
        assert!(Manifest::parse("scores x 8 f.txt\n").is_err());
    }

    #[test]
    fn empty_ok() {
        let m = Manifest::parse("# nothing\n").unwrap();
        assert!(m.entries.is_empty());
        assert!(m.best_for("scores", 1).is_none());
    }
}
