//! Pluggable compute backends for the per-iteration linear algebra.
//!
//! The two `O(ms)` operations of every training iteration — the score
//! matvec `p = X·w` and the subgradient assembly `a = Xᵀ·coeffs` — are
//! routed through this trait so the coordinator can execute them either
//! with native Rust kernels ([`NativeBackend`], sparse CSR/CSC or dense)
//! or with the AOT-compiled XLA executables lowered from JAX/Pallas
//! (`runtime::XlaBackend`, behind the `xla` feature). Python is never on
//! this path: the XLA backend loads pre-built `artifacts/*.hlo.txt`.

use crate::linalg::simd;
use crate::linalg::{CscMatrix, CsrView};
use crate::runtime::pool::{Task, WorkerPool};
use std::sync::Arc;

/// Backend interface. `prepare` is called once per dataset so backends
/// can build auxiliary structures (CSC copy, padded dense tiles, device
/// buffers) off the hot path.
///
/// The matrix arrives as a borrowed [`CsrView`], so one backend serves
/// both owned in-memory datasets and memory-mapped pallas stores with
/// zero copies.
pub trait ComputeBackend {
    fn name(&self) -> &'static str;
    /// One-time per-dataset setup.
    fn prepare(&mut self, _x: CsrView<'_>) {}
    /// `p = X·w` (length = rows).
    fn scores(&mut self, x: CsrView<'_>, w: &[f64]) -> Vec<f64>;
    /// `a = Xᵀ·coeffs` (length = cols).
    fn grad(&mut self, x: CsrView<'_>, coeffs: &[f64]) -> Vec<f64>;
}

/// Native Rust kernels. With `use_csc`, the gradient runs over a
/// column-compressed copy (gather instead of scatter) — the "two copies
/// of the data matrix" trade-off the paper describes in its Fig.-3
/// discussion; costs ~2× matrix memory.
pub struct NativeBackend {
    use_csc: bool,
    csc: Option<CscMatrix>,
}

impl NativeBackend {
    pub fn new() -> Self {
        NativeBackend { use_csc: false, csc: None }
    }

    pub fn with_csc() -> Self {
        NativeBackend { use_csc: true, csc: None }
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl ComputeBackend for NativeBackend {
    fn name(&self) -> &'static str {
        if self.use_csc {
            "native+csc"
        } else {
            "native"
        }
    }

    fn prepare(&mut self, x: CsrView<'_>) {
        if self.use_csc {
            self.csc = Some(x.to_csc());
        }
    }

    fn scores(&mut self, x: CsrView<'_>, w: &[f64]) -> Vec<f64> {
        let mut p = vec![0.0; x.rows()];
        x.matvec(w, &mut p);
        p
    }

    fn grad(&mut self, x: CsrView<'_>, coeffs: &[f64]) -> Vec<f64> {
        let mut a = vec![0.0; x.cols()];
        match (&self.csc, self.use_csc) {
            (Some(csc), true) => csc.matvec_t(coeffs, &mut a),
            _ => x.matvec_t(coeffs, &mut a),
        }
        a
    }
}

/// Fixed chunk count for the parallel gradient's row partition. Constant
/// (independent of the thread count and the data) so the reduction
/// topology — and therefore the floating-point result — is stable: the
/// same dataset and coefficients produce bit-identical gradients whether
/// one thread or sixteen execute the chunks. Deliberately *not* the
/// adaptive [`crate::linalg::ops::adaptive_chunks`] plan: the gradient's
/// partial sums re-associate with the chunk plan, so an adaptive count
/// would break bit-identity across thread counts. (The argsort and the
/// sharded oracle are adaptive because their results are exact for any
/// chunking.)
const GRAD_CHUNKS: usize = 16;

/// Multi-threaded native kernels on a persistent work-stealing
/// [`WorkerPool`].
///
/// - `scores`: rows are dealt to cache-sized contiguous ranges
///   ([`crate::runtime::cache::sized_chunks`], floored at the adaptive
///   plan) — individually stealable tasks, finer than the worker count,
///   so rows of uneven density (sparse corpora are Zipf-skewed too)
///   balance across threads while each chunk's CSR bytes fit a cache
///   fraction. Each output score is a single row dot product, so the
///   result is bit-identical to the serial [`NativeBackend`] regardless
///   of the partition or the scheduling.
/// - `grad`: rows are dealt to `GRAD_CHUNKS` fixed chunks — already
///   one stealable task each — accumulating a dense partial
///   `Xᵀ·coeffs`; the partials are then combined by a fixed-topology
///   pairwise tree reduction. Float sums re-associate relative to the
///   serial scatter, so the gradient can differ from [`NativeBackend`]
///   in the last bits — but never between runs or across thread counts:
///   the chunk *contents* and the reduction order are fixed, and the
///   pool only decides which thread runs which chunk. Each task zeroes
///   its own partial (first touch: the accumulation pages belong to the
///   worker that scatters into them), and the reduced result is *taken*
///   out of slot 0, not cloned.
///
/// Both sweeps run their inner loops through the [`simd`] kernel
/// dispatch point, which is bit-invisible by construction
/// (docs/DETERMINISM.md "Kernel dispatch").
pub struct ParallelBackend {
    pool: Arc<WorkerPool>,
    /// Per-chunk gradient partials, reused across iterations (slot 0 is
    /// re-grown each call after being handed to the caller).
    grad_parts: Vec<Vec<f64>>,
}

impl ParallelBackend {
    /// Build with a private pool. Prefer [`Self::with_pool`] inside the
    /// trainer so the backend and the sharded oracle share one pool.
    pub fn new(n_threads: usize) -> Self {
        Self::with_pool(Arc::new(WorkerPool::new(n_threads)))
    }

    /// Build on an existing persistent pool.
    pub fn with_pool(pool: Arc<WorkerPool>) -> Self {
        ParallelBackend { pool, grad_parts: Vec::new() }
    }

    pub fn n_threads(&self) -> usize {
        self.pool.n_threads()
    }

    /// The persistent pool this backend executes on.
    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }
}

impl ComputeBackend for ParallelBackend {
    fn name(&self) -> &'static str {
        "native-par"
    }

    fn scores(&mut self, x: CsrView<'_>, w: &[f64]) -> Vec<f64> {
        assert_eq!(w.len(), x.cols());
        let m = x.rows();
        let mut out = vec![0.0; m];
        if self.n_threads() <= 1 || m <= 1 {
            x.matvec(w, &mut out);
            return out;
        }
        // One stealable task per cache-sized chunk (not per worker):
        // each score is an independent row dot, so the chunk plan cannot
        // change a bit; surplus tasks let the stealing pool absorb
        // row-density skew, and the cache sizing keeps a chunk's CSR
        // bytes resident while a worker streams them.
        let bytes = x.nnz() * 12 + m * 8; // u32 idx + f64 val per nnz, f64 out per row
        let chunks = crate::runtime::cache::sized_chunks(self.n_threads(), bytes).min(m);
        simd::note_pass(simd::active());
        let mut tasks: Vec<Task> = Vec::with_capacity(chunks);
        {
            let mut rest: &mut [f64] = &mut out;
            let mut lo = 0usize;
            for t in 0..chunks {
                let hi = m * (t + 1) / chunks;
                // Move the remainder out before splitting so the tail can
                // be carried to the next iteration.
                let (head, tail) = { rest }.split_at_mut(hi - lo);
                let base = lo;
                tasks.push(Box::new(move || {
                    for (r, o) in head.iter_mut().enumerate() {
                        *o = x.row_dot(base + r, w);
                    }
                }));
                rest = tail;
                lo = hi;
            }
        }
        self.pool.run(tasks);
        out
    }

    fn grad(&mut self, x: CsrView<'_>, coeffs: &[f64]) -> Vec<f64> {
        let m = x.rows();
        let n = x.cols();
        assert_eq!(coeffs.len(), m);
        let chunks = GRAD_CHUNKS.min(m).max(1);
        self.grad_parts.resize_with(chunks, Vec::new);
        let k = simd::active();
        simd::note_pass(k);
        // Each task zeroes its own partial before scattering: when the
        // dimension is unchanged that is one `fill(0.0)` over memory the
        // same worker is about to write (no realloc, no serial zeroing
        // sweep on the caller, and on NUMA hosts the pages are first
        // touched by the thread that accumulates into them).
        let fill = |part: &mut Vec<f64>, c: usize| {
            if part.len() == n {
                part.fill(0.0);
            } else {
                part.clear();
                part.resize(n, 0.0);
            }
            let lo = m * c / chunks;
            let hi = m * (c + 1) / chunks;
            for i in lo..hi {
                let vi = coeffs[i];
                if vi != 0.0 {
                    let (idx, val) = x.row(i);
                    simd::scatter_axpy(k, idx, val, vi, part);
                }
            }
        };
        if self.n_threads() <= 1 {
            for (c, part) in self.grad_parts.iter_mut().enumerate() {
                fill(part, c);
            }
        } else {
            // One stealable task per fixed chunk; the work-stealing
            // pool balances them across however many workers are free.
            // Chunk contents are fixed, so scheduling cannot influence
            // the result.
            let fill = &fill;
            let mut tasks: Vec<Task> = Vec::with_capacity(chunks);
            for (c, part) in self.grad_parts.iter_mut().enumerate() {
                tasks.push(Box::new(move || fill(part, c)));
            }
            self.pool.run(tasks);
        }
        // Fixed-topology pairwise tree reduction over the chunk partials.
        let mut stride = 1usize;
        while stride < chunks {
            let mut base = 0usize;
            while base + stride < chunks {
                let (left, right) = self.grad_parts.split_at_mut(base + stride);
                let dst = &mut left[base];
                let src = &right[0];
                for (d, &s) in dst.iter_mut().zip(src.iter()) {
                    *d += s;
                }
                base += 2 * stride;
            }
            stride *= 2;
        }
        // Hand the reduced partial to the caller instead of cloning it
        // (the clone was a full O(n) copy per BMRM iteration); the next
        // call's fill re-grows slot 0 from empty.
        std::mem::take(&mut self.grad_parts[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn csr_and_csc_paths_agree() {
        let mut rng = Rng::new(701);
        let mut triplets = Vec::new();
        for i in 0..50 {
            for j in 0..30 {
                if rng.bool(0.2) {
                    triplets.push((i, j, rng.normal()));
                }
            }
        }
        let x = CsrMatrix::from_triplets(50, 30, triplets);
        let w: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let c: Vec<f64> = (0..50).map(|_| rng.normal()).collect();

        let mut plain = NativeBackend::new();
        let mut twocopy = NativeBackend::with_csc();
        plain.prepare(x.view());
        twocopy.prepare(x.view());

        assert_eq!(plain.scores(x.view(), &w), twocopy.scores(x.view(), &w));
        let g1 = plain.grad(x.view(), &c);
        let g2 = twocopy.grad(x.view(), &c);
        for (a, b) in g1.iter().zip(&g2) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn parallel_backend_matches_serial_and_is_thread_count_invariant() {
        let mut rng = Rng::new(702);
        let mut triplets = Vec::new();
        for i in 0..137 {
            for j in 0..40 {
                if rng.bool(0.15) {
                    triplets.push((i, j, rng.normal()));
                }
            }
        }
        let x = CsrMatrix::from_triplets(137, 40, triplets);
        let w: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let c: Vec<f64> = (0..137).map(|_| rng.normal()).collect();

        let mut serial = NativeBackend::new();
        serial.prepare(x.view());
        let p_ref = serial.scores(x.view(), &w);
        let g_ref = serial.grad(x.view(), &c);

        let mut g_one: Option<Vec<f64>> = None;
        for threads in [1, 2, 5, 32] {
            let mut par = ParallelBackend::new(threads);
            par.prepare(x.view());
            // Scores are per-row dot products: bit-identical to serial.
            assert_eq!(par.scores(x.view(), &w), p_ref, "{threads} threads");
            let g = par.grad(x.view(), &c);
            for (a, b) in g.iter().zip(&g_ref) {
                assert!((a - b).abs() < 1e-10, "{threads} threads: {a} vs {b}");
            }
            // Fixed chunk plan + fixed reduction topology: the gradient
            // is bit-identical across thread counts.
            match &g_one {
                None => g_one = Some(g),
                Some(first) => assert_eq!(&g, first, "{threads} threads"),
            }
        }
    }

    #[test]
    fn parallel_backend_grad_is_stable_across_repeated_calls() {
        // Regression: grad hands its reduced partial to the caller with
        // `mem::take` instead of cloning, so the next iteration must
        // rebuild slot 0 from empty and still produce identical bits —
        // including after the input dimensions change.
        let mut rng = Rng::new(703);
        let mut triplets = Vec::new();
        for i in 0..90 {
            for j in 0..25 {
                if rng.bool(0.2) {
                    triplets.push((i, j, rng.normal()));
                }
            }
        }
        let x = CsrMatrix::from_triplets(90, 25, triplets);
        let c: Vec<f64> = (0..90).map(|_| rng.normal()).collect();
        let mut par = ParallelBackend::new(4);
        par.prepare(x.view());
        let first = par.grad(x.view(), &c);
        let again = par.grad(x.view(), &c);
        assert_eq!(first, again, "taken partial must be rebuilt");

        let y = CsrMatrix::from_triplets(7, 60, vec![(3, 59, 2.5)]);
        let g = par.grad(y.view(), &[0.0, 0.0, 0.0, 2.0, 0.0, 0.0, 0.0]);
        assert_eq!(g.len(), 60, "partials must re-size with the data");
        assert_eq!(g[59], 5.0);
    }

    #[test]
    fn parallel_backend_degenerate_shapes() {
        let x = CsrMatrix::from_triplets(0, 3, vec![]);
        let mut par = ParallelBackend::new(4);
        assert!(par.scores(x.view(), &[0.0; 3]).is_empty());
        assert_eq!(par.grad(x.view(), &[]), vec![0.0; 3]);

        let x = CsrMatrix::from_triplets(2, 2, vec![(0, 0, 1.0), (1, 1, 2.0)]);
        let mut par = ParallelBackend::new(8);
        assert_eq!(par.scores(x.view(), &[3.0, 4.0]), vec![3.0, 8.0]);
        assert_eq!(par.grad(x.view(), &[1.0, 1.0]), vec![1.0, 2.0]);
    }
}
