//! Training configuration: method registry, hyper-parameters, and the
//! λ ↔ C conversion the paper describes (§5.1).

/// Which loss/subgradient oracle (and hence which algorithm from the
/// paper's evaluation) drives training.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// TreeRSVM — Algorithm 3 with the order-statistics red-black tree.
    Tree,
    /// TreeRSVM with the duplicate-merging (`nodesize`) tree variant.
    TreeDedup,
    /// TreeRSVM with the Fenwick counter (ablation).
    TreeFenwick,
    /// PairRSVM — explicit O(m²) pair iteration under the same BMRM.
    Pair,
    /// SVM^rank stand-in — the r-level algorithm of Joachims (2006).
    RLevel,
    /// PRSVM — truncated Newton on the squared pairwise hinge, with the
    /// faithful O(m²)-memory pair materialization.
    Prsvm,
    /// PRSVM objective with our O(m log m) sum-augmented-tree oracle
    /// (the Chapelle & Keerthi "improved version" — extension feature).
    PrsvmTree,
}

impl Method {
    pub fn parse(s: &str) -> Option<Method> {
        Some(match s {
            "tree" | "treersvm" => Method::Tree,
            "tree-dedup" | "dedup" => Method::TreeDedup,
            "tree-fenwick" | "fenwick" => Method::TreeFenwick,
            "pair" | "pairrsvm" => Method::Pair,
            "rlevel" | "svmrank" => Method::RLevel,
            "prsvm" | "squared" | "newton" => Method::Prsvm,
            "prsvm-tree" | "squared-tree" => Method::PrsvmTree,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Tree => "tree",
            Method::TreeDedup => "tree-dedup",
            Method::TreeFenwick => "tree-fenwick",
            Method::Pair => "pair",
            Method::RLevel => "rlevel",
            Method::Prsvm => "prsvm",
            Method::PrsvmTree => "prsvm-tree",
        }
    }

    /// All methods, for bench sweeps.
    pub fn all() -> &'static [Method] {
        &[
            Method::Tree,
            Method::TreeDedup,
            Method::TreeFenwick,
            Method::Pair,
            Method::RLevel,
            Method::Prsvm,
            Method::PrsvmTree,
        ]
    }
}

/// Which backend executes the O(ms) linear algebra.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Native CSR kernels.
    Native,
    /// Native with an extra CSC copy for the gradient (paper's
    /// two-copies trade-off).
    NativeCsc,
    /// AOT-compiled XLA executables via PJRT (dense tiles); requires
    /// `make artifacts`.
    Xla,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<BackendKind> {
        Some(match s {
            "native" => BackendKind::Native,
            "native-csc" | "csc" => BackendKind::NativeCsc,
            "xla" | "pjrt" => BackendKind::Xla,
            _ => return None,
        })
    }
}

/// Feature normalization applied before optimization.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Normalize {
    /// Train on the features exactly as loaded (the default).
    None,
    /// Divide every feature column by its ℓ2 norm over the training
    /// set. The norms come from the pallas store's cached column stats
    /// when the source carries them (skipping the `O(m·s)` scan) and
    /// from an identical row-major recomputation otherwise — training
    /// is bit-identical either way, and matches training on explicitly
    /// pre-normalized input (pinned in `tests/store.rs`). The trained
    /// weights live in the *normalized* feature space: score raw data
    /// with the same normalization applied.
    L2Col,
}

impl Normalize {
    pub fn parse(s: &str) -> Option<Normalize> {
        Some(match s {
            "none" => Normalize::None,
            "l2-col" | "l2col" => Normalize::L2Col,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Normalize::None => "none",
            Normalize::L2Col => "l2-col",
        }
    }
}

/// Full training configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub method: Method,
    pub backend: BackendKind,
    /// Regularizer weight λ in `R_emp + λ‖w‖²` (paper: 1e-1 for Cadata,
    /// 1e-5 for Reuters).
    pub lambda: f64,
    /// BMRM gap tolerance ε (paper: 1e-3; for PRSVM the Newton decrement
    /// tolerance 1e-6 is derived as `epsilon * 1e-3`).
    pub epsilon: f64,
    pub max_iter: usize,
    /// Enable the OCAS-style line search extension.
    pub line_search: bool,
    /// Directory with `manifest.txt` + `*.hlo.txt` for the XLA backend.
    pub artifacts_dir: String,
    /// Emit per-iteration JSON lines to stderr.
    pub verbose: bool,
    /// Worker threads for the sharded oracle and the parallel native
    /// backend; `0` (the default) resolves to the host's available
    /// parallelism. Any value produces bit-identical training results —
    /// the shard/chunk reductions are order-fixed (see
    /// [`crate::losses::ShardedTreeOracle`] and
    /// [`crate::compute::ParallelBackend`]; the contract is written
    /// down in `docs/DETERMINISM.md`).
    pub n_threads: usize,
    /// Feature normalization applied before optimization (CLI
    /// `--normalize`).
    pub normalize: Normalize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            method: Method::Tree,
            backend: BackendKind::Native,
            lambda: 1e-2,
            epsilon: 1e-3,
            max_iter: 2000,
            line_search: false,
            artifacts_dir: "artifacts".to_string(),
            verbose: false,
            n_threads: 0,
            normalize: Normalize::None,
        }
    }
}

impl TrainConfig {
    /// SVM^rank / PRSVM use `C` multiplied into an *unnormalized* risk;
    /// the paper gives the conversion `C = 1/(λN)`.
    pub fn c_equivalent(&self, n_pairs: f64) -> f64 {
        1.0 / (self.lambda * n_pairs)
    }

    /// The concrete worker count: `n_threads`, with `0` resolved to the
    /// host's available parallelism (1 if that probe fails).
    pub fn resolved_threads(&self) -> usize {
        crate::util::resolve_threads(self.n_threads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_round_trip() {
        for &m in Method::all() {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("svmrank"), Some(Method::RLevel));
        assert_eq!(Method::parse("nope"), None);
    }

    #[test]
    fn normalize_parse_round_trip() {
        for n in [Normalize::None, Normalize::L2Col] {
            assert_eq!(Normalize::parse(n.name()), Some(n));
        }
        assert_eq!(Normalize::parse("l2col"), Some(Normalize::L2Col));
        assert_eq!(Normalize::parse("zscore"), None);
        assert_eq!(TrainConfig::default().normalize, Normalize::None);
    }

    #[test]
    fn backend_parse() {
        assert_eq!(BackendKind::parse("xla"), Some(BackendKind::Xla));
        assert_eq!(BackendKind::parse("native"), Some(BackendKind::Native));
        assert_eq!(BackendKind::parse("zzz"), None);
    }

    #[test]
    fn c_conversion() {
        let cfg = TrainConfig { lambda: 0.1, ..Default::default() };
        assert!((cfg.c_equivalent(100.0) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn thread_resolution() {
        let auto = TrainConfig::default();
        assert_eq!(auto.n_threads, 0);
        assert!(auto.resolved_threads() >= 1);
        let fixed = TrainConfig { n_threads: 3, ..Default::default() };
        assert_eq!(fixed.resolved_threads(), 3);
    }
}
