//! Read-only memory mapping with a buffered-read fallback.
//!
//! The offline registry carries neither `libc` nor `memmap2`, so on
//! Linux x86_64/aarch64 the `mmap`/`munmap` syscalls are issued directly
//! via inline assembly (`PROT_READ`, `MAP_PRIVATE` — the kernel pages
//! the file in lazily, which is what makes opening a multi-gigabyte
//! store O(1) and lets training stream datasets larger than RAM).
//! Everywhere else — or if the syscall fails — the file is read into an
//! 8-byte-aligned owned buffer, preserving the same `&[u8]` interface
//! (correct, just not out-of-core).
//!
//! The same no-libc discipline covers the paging hints: [`Mmap::advise`]
//! issues a raw `madvise` (`SEQUENTIAL` before the reader's streaming
//! checksum pass, `WILLNEED` ahead of the trainer's first sweep) and
//! [`fadvise_sequential`] a raw `posix_fadvise` for the converter's
//! buffered read pass. Both are pure hints: they degrade to no-ops off
//! Linux, for the owned-buffer fallback, and on any syscall failure.

use anyhow::{Context, Result};
use std::path::Path;

/// Paging-pattern hints for [`Mmap::advise`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Advice {
    /// Expect sequential access: more aggressive readahead
    /// (`MADV_SEQUENTIAL`).
    Sequential,
    /// Expect access soon: start paging in now (`MADV_WILLNEED`).
    WillNeed,
}

/// An immutable byte view of a file: either a kernel mapping or an
/// owned aligned buffer. The base address is always at least 8-byte
/// aligned (page-aligned for real mappings; a `u64` allocation for the
/// fallback), which is what lets the store cast sections in place.
pub struct Mmap {
    ptr: *const u8,
    len: usize,
    backing: Backing,
}

enum Backing {
    /// A live kernel mapping; unmapped on drop.
    Mapped,
    /// Owned fallback buffer (kept for the allocation; read via `ptr`).
    #[allow(dead_code)]
    Owned(Vec<u64>),
}

// SAFETY: the mapping is read-only and private; the fallback buffer is
// owned. Either way the bytes are immutable for the struct's lifetime.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

impl Mmap {
    /// Map (or read) a whole file.
    pub fn open(path: impl AsRef<Path>) -> Result<Mmap> {
        let path = path.as_ref();
        let file = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
        let len = file
            .metadata()
            .with_context(|| format!("stat {}", path.display()))?
            .len();
        let len = usize::try_from(len).context("file too large for this address space")?;
        if len == 0 {
            return Ok(Mmap { ptr: std::ptr::NonNull::<u64>::dangling().as_ptr() as *const u8, len: 0, backing: Backing::Owned(Vec::new()) });
        }
        if let Some(ptr) = sys::mmap_readonly(&file, len) {
            return Ok(Mmap { ptr, len, backing: Backing::Mapped });
        }
        Self::read_fallback(file, len)
    }

    fn read_fallback(mut file: std::fs::File, len: usize) -> Result<Mmap> {
        use std::io::Read;
        let mut buf = vec![0u64; len.div_ceil(8)];
        // SAFETY: the u64 buffer spans ≥ len bytes; u8 has no alignment
        // requirement. The buffer is freshly owned and unaliased.
        let dst =
            unsafe { std::slice::from_raw_parts_mut(buf.as_mut_ptr() as *mut u8, len) };
        file.read_exact(dst).context("reading store file")?;
        let ptr = buf.as_ptr() as *const u8;
        Ok(Mmap { ptr, len, backing: Backing::Owned(buf) })
    }

    /// The mapped/read bytes.
    #[inline]
    pub fn bytes(&self) -> &[u8] {
        // SAFETY: ptr/len describe either a live mapping (valid until
        // munmap in Drop) or the owned buffer (valid until drop).
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// True when backed by a real kernel mapping (false: owned buffer).
    pub fn is_mapped(&self) -> bool {
        matches!(self.backing, Backing::Mapped)
    }

    /// Hint the kernel about the upcoming access pattern (`madvise`).
    /// No-op for the owned-buffer fallback, off Linux, or on failure —
    /// advice never affects correctness, only paging behavior.
    pub fn advise(&self, advice: Advice) {
        if self.len == 0 {
            return;
        }
        if let Backing::Mapped = self.backing {
            let adv = match advice {
                Advice::Sequential => 2, // MADV_SEQUENTIAL
                Advice::WillNeed => 3,   // MADV_WILLNEED
            };
            sys::madvise(self.ptr, self.len, adv);
        }
    }
}

/// `posix_fadvise(fd, 0, 0, POSIX_FADV_SEQUENTIAL)` — tell the kernel a
/// plain (non-mapped) file is about to be streamed start to end, so
/// readahead ramps up immediately. Used by the converter's parse pass;
/// a hint only, no-op off Linux or on failure.
pub fn fadvise_sequential(file: &std::fs::File) {
    sys::fadvise_sequential(file);
}

impl Drop for Mmap {
    fn drop(&mut self) {
        if let Backing::Mapped = self.backing {
            sys::munmap(self.ptr, self.len);
        }
    }
}

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod sys {
    use std::os::unix::io::AsRawFd;

    const PROT_READ: usize = 1;
    const MAP_PRIVATE: usize = 2;

    /// `mmap(NULL, len, PROT_READ, MAP_PRIVATE, fd, 0)`; None on error
    /// (the caller falls back to reading).
    pub fn mmap_readonly(file: &std::fs::File, len: usize) -> Option<*const u8> {
        let fd = file.as_raw_fd() as isize;
        let ret: isize;
        #[cfg(target_arch = "x86_64")]
        // SAFETY: a well-formed mmap syscall; all arguments are plain
        // integers and the kernel validates them.
        unsafe {
            std::arch::asm!(
                "syscall",
                inlateout("rax") 9isize => ret, // __NR_mmap
                in("rdi") 0usize,
                in("rsi") len,
                in("rdx") PROT_READ,
                in("r10") MAP_PRIVATE,
                in("r8") fd,
                in("r9") 0usize,
                lateout("rcx") _,
                lateout("r11") _,
                options(nostack)
            );
        }
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above, aarch64 calling convention.
        unsafe {
            std::arch::asm!(
                "svc 0",
                in("x8") 222usize, // __NR_mmap
                inlateout("x0") 0usize => ret,
                in("x1") len,
                in("x2") PROT_READ,
                in("x3") MAP_PRIVATE,
                in("x4") fd,
                in("x5") 0usize,
                options(nostack)
            );
        }
        // Errors come back as -errno in [-4095, -1].
        if (-4095..0).contains(&ret) {
            None
        } else {
            Some(ret as *const u8)
        }
    }

    pub fn munmap(ptr: *const u8, len: usize) {
        let addr = ptr as usize;
        let _ret: isize;
        #[cfg(target_arch = "x86_64")]
        // SAFETY: unmapping a region this module mapped.
        unsafe {
            std::arch::asm!(
                "syscall",
                inlateout("rax") 11isize => _ret, // __NR_munmap
                in("rdi") addr,
                in("rsi") len,
                lateout("rcx") _,
                lateout("r11") _,
                options(nostack)
            );
        }
        #[cfg(target_arch = "aarch64")]
        // SAFETY: unmapping a region this module mapped.
        unsafe {
            std::arch::asm!(
                "svc 0",
                in("x8") 215usize, // __NR_munmap
                inlateout("x0") addr => _ret,
                in("x1") len,
                options(nostack)
            );
        }
    }

    /// `madvise(addr, len, advice)` — paging hint on a mapped region.
    /// The return value is deliberately ignored: advice is best-effort.
    pub fn madvise(ptr: *const u8, len: usize, advice: usize) {
        let addr = ptr as usize;
        let _ret: isize;
        #[cfg(target_arch = "x86_64")]
        // SAFETY: a well-formed madvise syscall on a region this module
        // mapped; the kernel validates the arguments.
        unsafe {
            std::arch::asm!(
                "syscall",
                inlateout("rax") 28isize => _ret, // __NR_madvise
                in("rdi") addr,
                in("rsi") len,
                in("rdx") advice,
                lateout("rcx") _,
                lateout("r11") _,
                options(nostack)
            );
        }
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above, aarch64 calling convention.
        unsafe {
            std::arch::asm!(
                "svc 0",
                in("x8") 233usize, // __NR_madvise
                inlateout("x0") addr => _ret,
                in("x1") len,
                in("x2") advice,
                options(nostack)
            );
        }
    }

    /// `posix_fadvise(fd, 0, 0, POSIX_FADV_SEQUENTIAL)` — whole-file
    /// sequential-readahead hint; result ignored (best-effort).
    pub fn fadvise_sequential(file: &std::fs::File) {
        let fd = file.as_raw_fd() as isize;
        const POSIX_FADV_SEQUENTIAL: usize = 2;
        let _ret: isize;
        #[cfg(target_arch = "x86_64")]
        // SAFETY: a well-formed fadvise64 syscall; plain integer
        // arguments, validated by the kernel.
        unsafe {
            std::arch::asm!(
                "syscall",
                inlateout("rax") 221isize => _ret, // __NR_fadvise64
                in("rdi") fd,
                in("rsi") 0usize, // offset
                in("rdx") 0usize, // len (0 = to end of file)
                in("r10") POSIX_FADV_SEQUENTIAL,
                lateout("rcx") _,
                lateout("r11") _,
                options(nostack)
            );
        }
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above, aarch64 calling convention.
        unsafe {
            std::arch::asm!(
                "svc 0",
                in("x8") 223usize, // __NR_fadvise64_64
                inlateout("x0") fd => _ret,
                in("x1") 0usize,
                in("x2") 0usize,
                in("x3") POSIX_FADV_SEQUENTIAL,
                options(nostack)
            );
        }
    }
}

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
mod sys {
    /// No raw-syscall mapping on this target; always fall back to read.
    pub fn mmap_readonly(_file: &std::fs::File, _len: usize) -> Option<*const u8> {
        None
    }

    pub fn munmap(_ptr: *const u8, _len: usize) {}

    /// Paging hints are Linux-only; elsewhere they are no-ops.
    pub fn madvise(_ptr: *const u8, _len: usize, _advice: usize) {}

    pub fn fadvise_sequential(_file: &std::fs::File) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmp(name: &str, contents: &[u8]) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!("ranksvm_mmap_{}_{name}", std::process::id()));
        let mut f = std::fs::File::create(&p).unwrap();
        f.write_all(contents).unwrap();
        p
    }

    #[test]
    fn maps_file_contents() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let p = tmp("contents", &data);
        let m = Mmap::open(&p).unwrap();
        assert_eq!(m.len(), data.len());
        assert_eq!(m.bytes(), &data[..]);
        assert_eq!(m.bytes().as_ptr() as usize % 8, 0, "base must be 8-aligned");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn fallback_read_matches_mapping() {
        let data: Vec<u8> = (0..9999u32).flat_map(|x| x.to_le_bytes()).collect();
        let p = tmp("fallback", &data);
        let file = std::fs::File::open(&p).unwrap();
        let fb = Mmap::read_fallback(file, data.len()).unwrap();
        assert!(!fb.is_mapped());
        assert_eq!(fb.bytes(), &data[..]);
        assert_eq!(fb.bytes().as_ptr() as usize % 8, 0);
        let mapped = Mmap::open(&p).unwrap();
        assert_eq!(mapped.bytes(), fb.bytes());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn empty_file_and_missing_file() {
        let p = tmp("empty", b"");
        let m = Mmap::open(&p).unwrap();
        assert!(m.is_empty());
        assert_eq!(m.bytes(), b"");
        std::fs::remove_file(p).ok();
        assert!(Mmap::open("/nonexistent/ranksvm.pstore").is_err());
    }

    #[test]
    fn advice_is_harmless_on_all_backings() {
        let data = vec![3u8; 4096 * 2 + 17];
        let p = tmp("advice", &data);
        let mapped = Mmap::open(&p).unwrap();
        mapped.advise(Advice::Sequential);
        mapped.advise(Advice::WillNeed);
        assert_eq!(mapped.bytes(), &data[..]);
        let file = std::fs::File::open(&p).unwrap();
        fadvise_sequential(&file);
        let fb = Mmap::read_fallback(file, data.len()).unwrap();
        fb.advise(Advice::Sequential); // owned backing: no-op
        assert_eq!(fb.bytes(), &data[..]);
        let empty = tmp("advice_empty", b"");
        let m = Mmap::open(&empty).unwrap();
        m.advise(Advice::WillNeed); // zero-length: no-op
        std::fs::remove_file(p).ok();
        std::fs::remove_file(empty).ok();
    }

    #[test]
    fn drop_unmaps_without_crashing() {
        let data = vec![7u8; 4096 * 3 + 5];
        let p = tmp("drop", &data);
        for _ in 0..50 {
            let m = Mmap::open(&p).unwrap();
            assert_eq!(m.bytes()[4096], 7);
        }
        std::fs::remove_file(p).ok();
    }
}
