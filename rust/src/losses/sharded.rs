//! Query-sharded parallel subgradient oracle.
//!
//! The loss of §2 decomposes over disjoint example subsets two ways, and
//! this engine exploits both with `std::thread::scope` workers that keep
//! per-shard reusable tree buffers alive across BMRM iterations:
//!
//! **Query-grouped data** (the document-retrieval setting): the risk is
//! an average of per-query losses, so whole query groups are dealt to
//! shards (contiguous runs of groups, balanced by example count) and
//! each worker runs its own [`TreeOracle`] over its groups — the same
//! batch-parallel decomposition pursued by WMRB (Liu, 2017). Per-group
//! results are reduced serially *in group order*, so the output is
//! bit-identical to the serial [`super::QueryGrouped`] wrapper for every
//! shard count.
//!
//! **One global ranking**: the frequencies `c_i`/`d_i` of eqs. (5)–(6)
//! are *integer* dominance counts over the margin window
//! `W(i) = {j : 1 + p_i − p_j > 0}` (a prefix of the score-sorted order).
//! We split the sorted order into contiguous chunks; the worker owning
//! the chunk where `W(i)` *ends* computes `c_i` as
//!
//! - an incremental red-black-tree count over the partial chunk (exactly
//!   Algorithm 3's sweep, restricted to the chunk), plus
//! - one binary search per fully-covered earlier chunk against that
//!   chunk's pre-sorted label array (phase A, also parallel).
//!
//! `d_i` is the mirror image over suffix windows. Because every per-`i`
//! count is an exact integer decomposed by chunk, the assembled
//! `(loss, coeffs)` is **bit-identical to the single-threaded
//! [`TreeOracle`] for any shard count** — no floating-point reduction
//! enters until [`super::assemble_from_counts`], which runs serially on
//! the full count vectors. Wall-time per worker is
//! `O((m/S)·(log(m/S) + S·log(m/S)))` tree/binary-search steps; the
//! binary searches stream flat sorted arrays, which is what makes the
//! sharded oracle faster in practice on multi-core hosts (see
//! `benches/fig1_iteration_cost.rs`).
//!
//! Degenerate score distributions (e.g. all predictions within one
//! margin of each other, as at `w = 0`) collapse every window onto the
//! last chunk and serialize the sweep — correctness is unaffected.

use super::{assemble_from_counts, OracleOutput, RankingOracle};
use crate::linalg::ops::argsort_into;
use crate::losses::tree::TreeOracle;
use crate::rbtree::OsTree;

/// How examples are dealt to shards.
enum Plan {
    /// One global ranking: contiguous chunks of the score-sorted order.
    Global,
    /// Disjoint query groups (first-seen order, as in
    /// [`super::QueryGrouped`]), dealt to shards as contiguous group
    /// runs balanced by example count.
    Grouped {
        /// Example indices per group.
        groups: Vec<Vec<usize>>,
        /// Comparable pairs per group (fixed by the labels at build).
        group_pairs: Vec<f64>,
        /// Effective group count for averaging (groups with pairs).
        r_eff: f64,
        /// Per shard: `[lo, hi)` range of group indices.
        ranges: Vec<(usize, usize)>,
    },
}

/// Per-shard worker state, reused across oracle calls (and hence across
/// BMRM cutting-plane iterations — the trees and buffers are allocated
/// once and only grow).
struct ShardState {
    /// Incremental counter for the partial-chunk sweep (global mode).
    tree: OsTree,
    /// Counts for this shard's owned queries, in sweep order.
    c_out: Vec<u64>,
    d_out: Vec<u64>,
    /// Grouped mode: a full per-shard tree oracle plus gather buffers.
    oracle: TreeOracle,
    p_buf: Vec<f64>,
    y_buf: Vec<f64>,
    /// Grouped mode: concatenated per-group coefficient outputs plus
    /// `(group, offset, len, loss)` records.
    coeff_buf: Vec<f64>,
    meta: Vec<(usize, usize, usize, f64)>,
}

impl ShardState {
    fn new() -> Self {
        ShardState {
            tree: OsTree::new(),
            c_out: Vec::new(),
            d_out: Vec::new(),
            oracle: TreeOracle::new(),
            p_buf: Vec::new(),
            y_buf: Vec::new(),
            coeff_buf: Vec::new(),
            meta: Vec::new(),
        }
    }
}

/// Shared read-only view handed to the global-mode workers.
struct GlobalView<'a> {
    /// Chunk boundaries over sorted positions, length `n_shards + 1`.
    bounds: &'a [usize],
    /// Owned query ranges `[lo, hi)` per shard, forward sweep.
    fwd: &'a [(usize, usize)],
    /// Owned query ranges per shard, backward sweep.
    bwd: &'a [(usize, usize)],
    y_sorted: &'a [f64],
    /// Forward window ends `w(k)` (exclusive), nondecreasing in `k`.
    w_end: &'a [usize],
    /// Backward window starts `v(k)` (inclusive), nondecreasing in `k`.
    v_start: &'a [usize],
    /// Per-chunk sorted label arrays (phase A output).
    labels: &'a [Vec<f64>],
}

/// The parallel sharded oracle engine. Construct once per training set
/// (like [`super::QueryGrouped`]); evaluate once per BMRM iteration.
pub struct ShardedTreeOracle {
    n_shards: usize,
    plan: Plan,
    shards: Vec<ShardState>,
    /// Per-chunk sorted labels, outside [`ShardState`] so phase-B workers
    /// can read every *other* shard's array.
    sorted_labels: Vec<Vec<f64>>,
    // Per-eval scratch (global mode), reused across calls.
    pi: Vec<usize>,
    p_sorted: Vec<f64>,
    y_sorted: Vec<f64>,
    w_end: Vec<usize>,
    v_start: Vec<usize>,
    c: Vec<u64>,
    d: Vec<u64>,
}

impl ShardedTreeOracle {
    /// Build for `n_threads` workers over a fixed training label vector;
    /// `qid` enables query-group sharding (must align with `y`).
    pub fn new(n_threads: usize, qid: Option<&[u64]>, y: &[f64]) -> Self {
        let n_shards = n_threads.max(1);
        let plan = match qid {
            None => Plan::Global,
            Some(q) => {
                let (groups, group_pairs) = crate::losses::query::build_groups(q, y);
                let r_eff = group_pairs.iter().filter(|&&n| n > 0.0).count().max(1) as f64;
                let ranges = split_groups(&groups, n_shards);
                Plan::Grouped { groups, group_pairs, r_eff, ranges }
            }
        };
        ShardedTreeOracle {
            n_shards,
            plan,
            shards: (0..n_shards).map(|_| ShardState::new()).collect(),
            sorted_labels: Vec::new(),
            pi: Vec::new(),
            p_sorted: Vec::new(),
            y_sorted: Vec::new(),
            w_end: Vec::new(),
            v_start: Vec::new(),
            c: Vec::new(),
            d: Vec::new(),
        }
    }

    /// Number of shard workers.
    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Query-group count (None for a single global ranking).
    pub fn n_groups(&self) -> Option<usize> {
        match &self.plan {
            Plan::Global => None,
            Plan::Grouped { groups, .. } => Some(groups.len()),
        }
    }

    /// Per-shard `[lo, hi)` group-index ranges (None in global mode).
    /// Ranges are contiguous and non-overlapping: a query group is never
    /// split across shards.
    pub fn group_ranges(&self) -> Option<&[(usize, usize)]> {
        match &self.plan {
            Plan::Global => None,
            Plan::Grouped { ranges, .. } => Some(ranges),
        }
    }

    /// Total comparable pairs across groups (grouped mode reporting).
    pub fn total_pairs(&self) -> Option<f64> {
        match &self.plan {
            Plan::Global => None,
            Plan::Grouped { group_pairs, .. } => Some(group_pairs.iter().sum()),
        }
    }

    fn eval_global(&mut self, p: &[f64], y: &[f64], n_pairs: f64) -> OracleOutput {
        let m = p.len();
        assert_eq!(m, y.len());
        if m == 0 {
            return OracleOutput { loss: 0.0, coeffs: Vec::new() };
        }
        let n_shards = self.n_shards.min(m);

        // Shared setup — exactly TreeOracle's sort + gather.
        argsort_into(p, &mut self.pi);
        self.p_sorted.clear();
        self.p_sorted.extend(self.pi.iter().map(|&k| p[k]));
        self.y_sorted.clear();
        self.y_sorted.extend(self.pi.iter().map(|&k| y[k]));

        // Window extents via two-pointer scans, with the *same* float
        // predicates as the serial sweeps so the counted sets match
        // exactly. Forward: W(k) = [0, w_end[k]) with
        // w_end[k] = first j failing 1 + p_k − p_j > 0 (nondecreasing,
        // and ≥ k+1 since j = k always passes). Backward:
        // V(k) = [v_start[k], m) with v_start[k] = first j passing
        // 1 + p_j − p_k > 0 (nondecreasing, and ≤ k).
        self.w_end.clear();
        self.w_end.reserve(m);
        {
            let ps = &self.p_sorted;
            let mut j = 0usize;
            for k in 0..m {
                let pk = ps[k];
                while j < m && 1.0 + pk - ps[j] > 0.0 {
                    j += 1;
                }
                self.w_end.push(j);
            }
        }
        self.v_start.clear();
        self.v_start.reserve(m);
        {
            let ps = &self.p_sorted;
            let mut j = 0usize;
            for k in 0..m {
                let pk = ps[k];
                // Advance past the js that fail the serial predicate
                // 1 + p_j − p_k > 0 (labels are NaN-free here, so the
                // `<=` form is its exact negation).
                while j < m && 1.0 + ps[j] - pk <= 0.0 {
                    j += 1;
                }
                self.v_start.push(j);
            }
        }

        // Contiguous chunks of the sorted order.
        let bounds: Vec<usize> = (0..=n_shards).map(|s| s * m / n_shards).collect();

        // Ownership: shard s owns the forward queries whose window ends
        // inside its chunk, and the backward queries whose window starts
        // inside it. Both extent arrays are monotone, so the owned query
        // sets are contiguous `k` ranges found by binary search.
        let fwd: Vec<(usize, usize)> = (0..n_shards)
            .map(|s| {
                (
                    self.w_end.partition_point(|&w| w <= bounds[s]),
                    self.w_end.partition_point(|&w| w <= bounds[s + 1]),
                )
            })
            .collect();
        let bwd: Vec<(usize, usize)> = (0..n_shards)
            .map(|s| {
                (
                    self.v_start.partition_point(|&v| v < bounds[s]),
                    self.v_start.partition_point(|&v| v < bounds[s + 1]),
                )
            })
            .collect();

        // Phase A: per-chunk sorted label arrays (cross-chunk counting
        // substrate). Skipped for a single shard — there is no other
        // chunk to count against.
        self.sorted_labels.resize_with(n_shards, Vec::new);
        if n_shards > 1 {
            let y_sorted = &self.y_sorted;
            std::thread::scope(|scope| {
                for (s, lab) in self.sorted_labels.iter_mut().enumerate() {
                    let (lo, hi) = (bounds[s], bounds[s + 1]);
                    scope.spawn(move || {
                        lab.clear();
                        lab.extend_from_slice(&y_sorted[lo..hi]);
                        lab.sort_unstable_by(|a, b| {
                            a.partial_cmp(b).expect("NaN utility score")
                        });
                    });
                }
            });
        }

        // Phase B: each worker counts its owned queries.
        let view = GlobalView {
            bounds: &bounds,
            fwd: &fwd,
            bwd: &bwd,
            y_sorted: &self.y_sorted,
            w_end: &self.w_end,
            v_start: &self.v_start,
            labels: &self.sorted_labels,
        };
        if n_shards == 1 {
            global_worker(0, &view, &mut self.shards[0]);
        } else {
            std::thread::scope(|scope| {
                for (s, state) in self.shards.iter_mut().take(n_shards).enumerate() {
                    let view = &view;
                    scope.spawn(move || global_worker(s, view, state));
                }
            });
        }

        // Scatter the per-shard counts back to original example order and
        // assemble — serial and order-fixed, so the float result cannot
        // depend on the shard count.
        self.c.clear();
        self.c.resize(m, 0);
        self.d.clear();
        self.d.resize(m, 0);
        for s in 0..n_shards {
            let st = &self.shards[s];
            let (q_lo, q_hi) = fwd[s];
            for (t, k) in (q_lo..q_hi).enumerate() {
                self.c[self.pi[k]] = st.c_out[t];
            }
            let (b_lo, b_hi) = bwd[s];
            for (t, k) in (b_lo..b_hi).rev().enumerate() {
                self.d[self.pi[k]] = st.d_out[t];
            }
        }
        assemble_from_counts(p, &self.c, &self.d, n_pairs)
    }

    fn eval_grouped(&mut self, p: &[f64], y: &[f64]) -> OracleOutput {
        let m = p.len();
        assert_eq!(m, y.len());
        let Plan::Grouped { groups, group_pairs, r_eff, ranges } = &self.plan else {
            unreachable!("eval_grouped requires a grouped plan")
        };
        let r_eff = *r_eff;
        let shards = &mut self.shards;

        if shards.len() == 1 {
            grouped_worker(&mut shards[0], ranges[0], groups, group_pairs, p, y);
        } else {
            std::thread::scope(|scope| {
                for (s, state) in shards.iter_mut().enumerate() {
                    let range = ranges[s];
                    scope.spawn(move || grouped_worker(state, range, groups, group_pairs, p, y));
                }
            });
        }

        // Reduce in group order. Shards hold contiguous ascending group
        // runs, so iterating shards then their records reproduces the
        // serial QueryGrouped accumulation order bit-for-bit.
        let mut loss = 0.0;
        let mut coeffs = vec![0.0; m];
        for state in shards.iter() {
            for &(g, off, len, group_loss) in &state.meta {
                loss += group_loss / r_eff;
                let idx = &groups[g];
                debug_assert_eq!(len, idx.len());
                for (k, &i) in idx.iter().enumerate() {
                    coeffs[i] = state.coeff_buf[off + k] / r_eff;
                }
            }
        }
        OracleOutput { loss, coeffs }
    }
}

impl RankingOracle for ShardedTreeOracle {
    /// `n_pairs` normalizes the global mode; in grouped mode the
    /// per-group counts fixed at construction are authoritative (same
    /// contract as [`super::QueryGrouped`]).
    fn eval(&mut self, p: &[f64], y: &[f64], n_pairs: f64) -> OracleOutput {
        if matches!(self.plan, Plan::Global) {
            self.eval_global(p, y, n_pairs)
        } else {
            self.eval_grouped(p, y)
        }
    }

    fn name(&self) -> &'static str {
        "sharded-tree"
    }
}

/// Deal groups to `n_shards` contiguous runs balanced by example count.
/// Deterministic in the inputs; the last shard absorbs the remainder.
fn split_groups(groups: &[Vec<usize>], n_shards: usize) -> Vec<(usize, usize)> {
    let total: usize = groups.iter().map(|g| g.len()).sum();
    let mut ranges = Vec::with_capacity(n_shards);
    let mut lo = 0usize;
    let mut cum = 0usize;
    for s in 0..n_shards {
        let mut hi = lo;
        if s + 1 == n_shards {
            hi = groups.len();
        } else {
            let target = total * (s + 1) / n_shards;
            while hi < groups.len() && cum < target {
                cum += groups[hi].len();
                hi += 1;
            }
        }
        ranges.push((lo, hi));
        lo = hi;
    }
    ranges
}

/// Grouped-mode worker: evaluate this shard's query groups with its own
/// reusable tree oracle, recording per-group losses and coefficients.
fn grouped_worker(
    state: &mut ShardState,
    range: (usize, usize),
    groups: &[Vec<usize>],
    group_pairs: &[f64],
    p: &[f64],
    y: &[f64],
) {
    state.meta.clear();
    state.coeff_buf.clear();
    for g in range.0..range.1 {
        let ng = group_pairs[g];
        if ng == 0.0 {
            continue;
        }
        let idx = &groups[g];
        state.p_buf.clear();
        state.p_buf.extend(idx.iter().map(|&i| p[i]));
        state.y_buf.clear();
        state.y_buf.extend(idx.iter().map(|&i| y[i]));
        let out = state.oracle.eval(&state.p_buf, &state.y_buf, ng);
        let off = state.coeff_buf.len();
        state.coeff_buf.extend_from_slice(&out.coeffs);
        state.meta.push((g, off, idx.len(), out.loss));
    }
}

/// Global-mode worker: exact `c`/`d` counts for the queries whose margin
/// window ends (forward) or starts (backward) inside this shard's chunk.
fn global_worker(s: usize, v: &GlobalView, state: &mut ShardState) {
    let n_shards = v.fwd.len();

    // Forward sweep: c_k = |{j ∈ W(k) : y_j > y_k}|, decomposed as the
    // incremental tree over the partial chunk plus one binary search per
    // fully-covered earlier chunk.
    state.c_out.clear();
    state.tree.clear();
    let (q_lo, q_hi) = v.fwd[s];
    let mut j = v.bounds[s];
    for k in q_lo..q_hi {
        while j < v.w_end[k] {
            state.tree.insert(v.y_sorted[j]);
            j += 1;
        }
        let yk = v.y_sorted[k];
        let mut cnt = state.tree.count_larger(yk);
        for lab in &v.labels[..s] {
            cnt += (lab.len() - lab.partition_point(|&x| x <= yk)) as u64;
        }
        state.c_out.push(cnt);
    }

    // Backward sweep (descending k): d_k = |{j ∈ V(k) : y_j < y_k}|.
    state.d_out.clear();
    state.tree.clear();
    let (b_lo, b_hi) = v.bwd[s];
    let mut j = v.bounds[s + 1];
    for k in (b_lo..b_hi).rev() {
        while j > v.v_start[k] {
            j -= 1;
            state.tree.insert(v.y_sorted[j]);
        }
        let yk = v.y_sorted[k];
        let mut cnt = state.tree.count_smaller(yk);
        for lab in &v.labels[s + 1..n_shards] {
            cnt += lab.partition_point(|&x| x < yk) as u64;
        }
        state.d_out.push(cnt);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::losses::{count_comparable_pairs, PairOracle, QueryGrouped};
    use crate::util::rng::Rng;

    fn random_case(rng: &mut Rng, trial: usize) -> (Vec<f64>, Vec<f64>) {
        let m = 1 + rng.below(250);
        let y: Vec<f64> = match trial % 4 {
            0 => (0..m).map(|_| rng.normal()).collect(), // r ≈ m
            1 => (0..m).map(|_| rng.below(5) as f64).collect(), // heavy ties
            2 => (0..m).map(|_| rng.below(2) as f64).collect(), // bipartite
            _ => vec![3.0; m],                           // fully tied
        };
        // Quantized scores land exactly on margins; mix in ties.
        let p: Vec<f64> = match trial % 3 {
            0 => (0..m).map(|_| rng.normal() * 2.0).collect(),
            1 => (0..m).map(|_| (rng.below(30) as f64) / 7.0 - 2.0).collect(),
            _ => (0..m).map(|_| rng.below(3) as f64).collect(),
        };
        (p, y)
    }

    #[test]
    fn global_mode_bit_identical_to_tree_oracle() {
        let mut rng = Rng::new(9001);
        for trial in 0..60 {
            let (p, y) = random_case(&mut rng, trial);
            let n = count_comparable_pairs(&y) as f64;
            let mut reference = TreeOracle::new();
            let expect = reference.eval(&p, &y, n);
            for threads in [1, 2, 3, 8, 33] {
                let mut sharded = ShardedTreeOracle::new(threads, None, &y);
                let got = sharded.eval(&p, &y, n);
                assert_eq!(got.coeffs, expect.coeffs, "trial {trial}, {threads} shards");
                assert_eq!(
                    got.loss.to_bits(),
                    expect.loss.to_bits(),
                    "trial {trial}, {threads} shards"
                );
            }
        }
    }

    #[test]
    fn global_mode_matches_pair_oracle_counts() {
        let mut rng = Rng::new(9002);
        for trial in 0..40 {
            let (p, y) = random_case(&mut rng, trial);
            let n = count_comparable_pairs(&y) as f64;
            let mut pair = PairOracle::new();
            let expect = pair.eval(&p, &y, n);
            let mut sharded = ShardedTreeOracle::new(4, None, &y);
            let got = sharded.eval(&p, &y, n);
            assert_eq!(got.coeffs, expect.coeffs, "trial {trial}");
            assert!((got.loss - expect.loss).abs() <= 1e-12 * (1.0 + expect.loss));
        }
    }

    #[test]
    fn grouped_mode_bit_identical_to_query_grouped() {
        let mut rng = Rng::new(9003);
        for trial in 0..40 {
            let m = 1 + rng.below(200);
            let n_queries = 1 + rng.below(12);
            let qid: Vec<u64> = (0..m).map(|_| rng.below(n_queries) as u64 * 17).collect();
            let y: Vec<f64> = (0..m).map(|_| rng.below(4) as f64).collect();
            let p: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let mut serial = QueryGrouped::new(TreeOracle::new(), &qid, &y);
            let expect = serial.eval(&p, &y, serial.total_pairs());
            for threads in [1, 2, 8, 40] {
                let mut sharded = ShardedTreeOracle::new(threads, Some(&qid), &y);
                let got = sharded.eval(&p, &y, 0.0);
                assert_eq!(got.coeffs, expect.coeffs, "trial {trial}, {threads} shards");
                assert_eq!(
                    got.loss.to_bits(),
                    expect.loss.to_bits(),
                    "trial {trial}, {threads} shards"
                );
            }
        }
    }

    #[test]
    fn shard_plan_respects_query_boundaries() {
        let mut rng = Rng::new(9004);
        let m = 300;
        let qid: Vec<u64> = (0..m).map(|i| (i / 7) as u64).collect();
        let y: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        for threads in [1, 3, 8] {
            let oracle = ShardedTreeOracle::new(threads, Some(&qid), &y);
            let ranges = oracle.group_ranges().unwrap();
            let n_groups = oracle.n_groups().unwrap();
            assert_eq!(ranges.len(), threads);
            // Contiguous, non-overlapping cover of all groups: groups are
            // assigned whole — no group index appears in two shards.
            let mut expect_lo = 0;
            for &(lo, hi) in ranges {
                assert_eq!(lo, expect_lo);
                assert!(hi >= lo);
                expect_lo = hi;
            }
            assert_eq!(expect_lo, n_groups);
        }
    }

    #[test]
    fn degenerate_inputs() {
        let mut o = ShardedTreeOracle::new(4, None, &[]);
        let out = o.eval(&[], &[], 0.0);
        assert_eq!(out.loss, 0.0);
        assert!(out.coeffs.is_empty());

        // Fewer examples than shards.
        let y = [1.0, 2.0];
        let mut o = ShardedTreeOracle::new(8, None, &y);
        let out = o.eval(&[0.0, 0.5], &y, 1.0);
        let mut reference = TreeOracle::new();
        let expect = reference.eval(&[0.0, 0.5], &y, 1.0);
        assert_eq!(out.coeffs, expect.coeffs);

        // All-tied predictions: every window spans everything (the
        // worst-case serialization path).
        let y = [1.0, 2.0, 3.0, 4.0];
        let p = [0.0, 0.0, 0.0, 0.0];
        let n = count_comparable_pairs(&y) as f64;
        let mut o = ShardedTreeOracle::new(3, None, &y);
        let out = o.eval(&p, &y, n);
        assert!((out.loss - 1.0).abs() < 1e-12);
    }

    #[test]
    fn buffers_reused_across_calls_and_sizes() {
        let mut o = ShardedTreeOracle::new(4, None, &[1.0, 2.0]);
        let a = o.eval(&[0.5, 0.0], &[1.0, 2.0], 1.0);
        assert!(a.loss > 0.0);
        let b = o.eval(&[0.0, 5.0], &[1.0, 2.0], 1.0);
        assert_eq!(b.loss, 0.0);
        // Growing and shrinking sizes across calls.
        let y: Vec<f64> = (0..100).map(|i| (i % 7) as f64).collect();
        let p: Vec<f64> = (0..100).map(|i| ((i * 13) % 29) as f64 * 0.1).collect();
        let n = count_comparable_pairs(&y) as f64;
        let big = o.eval(&p, &y, n);
        let mut reference = TreeOracle::new();
        let expect = reference.eval(&p, &y, n);
        assert_eq!(big.coeffs, expect.coeffs);
        let small = o.eval(&[0.1, 0.0, 2.0], &[1.0, 2.0, 3.0], 3.0);
        let expect_small = reference.eval(&[0.1, 0.0, 2.0], &[1.0, 2.0, 3.0], 3.0);
        assert_eq!(small.coeffs, expect_small.coeffs);
    }

    #[test]
    fn split_groups_balances_and_covers() {
        let groups: Vec<Vec<usize>> = vec![
            (0..50).collect(),
            (50..60).collect(),
            (60..100).collect(),
            (100..105).collect(),
            (105..200).collect(),
        ];
        for s in 1..=7 {
            let ranges = split_groups(&groups, s);
            assert_eq!(ranges.len(), s);
            let mut lo = 0;
            for &(a, b) in &ranges {
                assert_eq!(a, lo);
                lo = b;
            }
            assert_eq!(lo, groups.len());
        }
    }
}
