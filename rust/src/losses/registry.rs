//! The loss registry — the single place a subgradient oracle is wired
//! into the trainer.
//!
//! Each [`LossSpec`] names a loss, its CLI spellings, the solver family
//! it runs under ([`SolverFamily`]), the parallel substrate its oracle
//! evaluates on ([`Substrate`]), and who owns normalization
//! ([`Normalization`]). BMRM-family losses carry a constructor that
//! builds their score-space [`RankingOracle`] from an [`OracleCtx`];
//! Newton-family losses carry a [`NewtonKind`] tag the trainer maps to
//! the squared-hinge Hessian oracles (those borrow the dataset and the
//! compute backend together, so they are built in
//! `coordinator/trainer.rs` rather than behind a constructor here —
//! the one documented asymmetry, see docs/LOSSES.md).
//!
//! Adding a loss is a registry entry plus an oracle implementation —
//! the checklist lives in docs/LOSSES.md, and `tests/properties.rs`
//! holds every entry to the thread-invariance and zero-safety contract
//! automatically. The table in docs/LOSSES.md is pinned to [`SPECS`] by
//! `tests/docs_spec.rs`.

use super::query::GroupIndex;
use super::sharded::{ShardedGroupOracle, ShardedTreeOracle};
use super::toppush::TopPushOracle;
use super::tree::{fenwick_oracle, TreeOracle};
use super::{GroupOracle, PairOracle, QueryGrouped, RLevelOracle, RankingOracle};
use crate::data::DatasetView;
use crate::runtime::WorkerPool;
use std::sync::Arc;

/// Which optimizer drives a loss.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolverFamily {
    /// BMRM cutting-plane over a score-space subgradient oracle.
    Bmrm,
    /// Truncated Newton over a generalized-Hessian oracle (PRSVM).
    Newton,
}

impl SolverFamily {
    pub fn name(&self) -> &'static str {
        match self {
            SolverFamily::Bmrm => "bmrm",
            SolverFamily::Newton => "newton",
        }
    }
}

/// Which parallel substrate evaluates the oracle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Substrate {
    /// The chunked sorted-order counting engine (tree oracle only):
    /// sharded in global mode *and* grouped mode.
    ShardedTree,
    /// The generic per-group engine: any [`GroupOracle`] on the
    /// work-stealing pool, serial group-order reduction.
    ShardedGroups,
    /// Serial evaluation (wrapped in [`QueryGrouped`] for grouped data).
    Serial,
}

impl Substrate {
    pub fn name(&self) -> &'static str {
        match self {
            Substrate::ShardedTree => "sharded-tree",
            Substrate::ShardedGroups => "sharded-groups",
            Substrate::Serial => "serial",
        }
    }
}

/// Which squared-hinge implementation backs a Newton-family loss.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NewtonKind {
    /// Faithful PRSVM: explicit pair materialization (O(m²) memory).
    MaterializedPairs,
    /// The sum-augmented-tree oracle (O(m log m) time, O(m) memory).
    SumTree,
}

/// Who owns the risk normalizer — the loss does, always; this records
/// *which* normalizer, for docs and for selecting comparable method
/// families in tests/benches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Normalization {
    /// Divide by the comparable-pair count `N = |{(i,j): y_i < y_j}|`
    /// (per group, averaged over effective groups) — the paper's
    /// pairwise family. All such losses optimize the same risk, which
    /// is what makes their objectives/test errors comparable.
    ComparablePairs,
    /// Divide by the per-group positive count `n₊` (TopPush).
    GroupPositives,
}

impl Normalization {
    pub fn name(&self) -> &'static str {
        match self {
            Normalization::ComparablePairs => "pairs",
            Normalization::GroupPositives => "positives",
        }
    }
}

/// Everything the trainer needs to build a BMRM score-space oracle.
pub struct OracleCtx<'a> {
    pub ds: &'a dyn DatasetView,
    /// Query-group index (None for one global ranking), shared with the
    /// pair count so both see identical group structure.
    pub index: Option<Arc<GroupIndex>>,
    /// The trainer's persistent work-stealing pool.
    pub pool: &'a Arc<WorkerPool>,
}

/// One registered loss.
pub struct LossSpec {
    /// Canonical CLI/JSON name.
    pub name: &'static str,
    /// Accepted alternate spellings.
    pub aliases: &'static [&'static str],
    /// One-line description (shown by `ranksvm losses`).
    pub about: &'static str,
    pub solver: SolverFamily,
    pub substrate: Substrate,
    pub normalization: Normalization,
    /// BMRM family: builds the score-space oracle. `None` ⇔ Newton.
    pub bmrm: Option<fn(OracleCtx<'_>) -> Box<dyn RankingOracle>>,
    /// Newton family: which Hessian oracle the trainer instantiates.
    /// `None` ⇔ BMRM.
    pub newton: Option<NewtonKind>,
}

/// Serial base oracle → grouped averaging wrapper when the dataset has
/// query structure (the [`Substrate::Serial`] arrangement).
fn grouped(base: Box<dyn RankingOracle>, index: Option<Arc<GroupIndex>>) -> Box<dyn RankingOracle> {
    match index {
        Some(gi) => Box::new(QueryGrouped::with_index(base, gi)),
        None => base,
    }
}

fn make_tree(ctx: OracleCtx<'_>) -> Box<dyn RankingOracle> {
    Box::new(match ctx.index {
        Some(gi) => ShardedTreeOracle::with_pool_index(Arc::clone(ctx.pool), gi),
        None => ShardedTreeOracle::with_pool(Arc::clone(ctx.pool), None, ctx.ds.y()),
    })
}

fn make_tree_dedup(ctx: OracleCtx<'_>) -> Box<dyn RankingOracle> {
    grouped(Box::new(TreeOracle::new_dedup()), ctx.index)
}

fn make_tree_fenwick(ctx: OracleCtx<'_>) -> Box<dyn RankingOracle> {
    grouped(Box::new(fenwick_oracle(ctx.ds.y())), ctx.index)
}

fn make_pair(ctx: OracleCtx<'_>) -> Box<dyn RankingOracle> {
    grouped(Box::new(PairOracle::new()), ctx.index)
}

fn make_rlevel(ctx: OracleCtx<'_>) -> Box<dyn RankingOracle> {
    grouped(Box::new(RLevelOracle::new()), ctx.index)
}

fn toppush_factory() -> Box<dyn GroupOracle> {
    Box::new(TopPushOracle::new())
}

fn make_toppush(ctx: OracleCtx<'_>) -> Box<dyn RankingOracle> {
    Box::new(ShardedGroupOracle::new(
        Arc::clone(ctx.pool),
        ctx.index,
        toppush_factory,
        "sharded-toppush",
    ))
}

pub static TREE: LossSpec = LossSpec {
    name: "tree",
    aliases: &["treersvm"],
    about: "TreeRSVM — pairwise hinge via the order-statistics red-black tree (the paper's \
            Algorithm 3), on the query-sharded parallel engine",
    solver: SolverFamily::Bmrm,
    substrate: Substrate::ShardedTree,
    normalization: Normalization::ComparablePairs,
    bmrm: Some(make_tree),
    newton: None,
};

pub static TREE_DEDUP: LossSpec = LossSpec {
    name: "tree-dedup",
    aliases: &["dedup"],
    about: "TreeRSVM with the duplicate-merging (nodesize) tree variant (ablation)",
    solver: SolverFamily::Bmrm,
    substrate: Substrate::Serial,
    normalization: Normalization::ComparablePairs,
    bmrm: Some(make_tree_dedup),
    newton: None,
};

pub static TREE_FENWICK: LossSpec = LossSpec {
    name: "tree-fenwick",
    aliases: &["fenwick"],
    about: "TreeRSVM with the Fenwick counter over the compressed label universe (ablation)",
    solver: SolverFamily::Bmrm,
    substrate: Substrate::Serial,
    normalization: Normalization::ComparablePairs,
    bmrm: Some(make_tree_fenwick),
    newton: None,
};

pub static PAIR: LossSpec = LossSpec {
    name: "pair",
    aliases: &["pairrsvm"],
    about: "PairRSVM — explicit O(m²) pairwise-hinge iteration under the same BMRM",
    solver: SolverFamily::Bmrm,
    substrate: Substrate::Serial,
    normalization: Normalization::ComparablePairs,
    bmrm: Some(make_pair),
    newton: None,
};

pub static RLEVEL: LossSpec = LossSpec {
    name: "rlevel",
    aliases: &["svmrank"],
    about: "SVM^rank stand-in — the r-level pairwise-hinge algorithm of Joachims (2006)",
    solver: SolverFamily::Bmrm,
    substrate: Substrate::Serial,
    normalization: Normalization::ComparablePairs,
    bmrm: Some(make_rlevel),
    newton: None,
};

pub static PRSVM: LossSpec = LossSpec {
    name: "prsvm",
    aliases: &["squared", "newton"],
    about: "PRSVM — truncated Newton on the squared pairwise hinge with faithful O(m²)-memory \
            pair materialization",
    solver: SolverFamily::Newton,
    substrate: Substrate::Serial,
    normalization: Normalization::ComparablePairs,
    bmrm: None,
    newton: Some(NewtonKind::MaterializedPairs),
};

pub static PRSVM_TREE: LossSpec = LossSpec {
    name: "prsvm-tree",
    aliases: &["squared-tree"],
    about: "PRSVM objective with the O(m log m) sum-augmented-tree oracle (extension)",
    solver: SolverFamily::Newton,
    substrate: Substrate::Serial,
    normalization: Normalization::ComparablePairs,
    bmrm: None,
    newton: Some(NewtonKind::SumTree),
};

pub static TOPPUSH: LossSpec = LossSpec {
    name: "toppush",
    aliases: &["top-push"],
    about: "TopPush (arXiv:1410.1462) — bipartite top-of-ranking hinge against the top-scoring \
            negative, O(m) per group, on the generic sharded group engine",
    solver: SolverFamily::Bmrm,
    substrate: Substrate::ShardedGroups,
    normalization: Normalization::GroupPositives,
    bmrm: Some(make_toppush),
    newton: None,
};

/// Every registered loss, in the canonical (docs/CLI) order.
pub static SPECS: [&LossSpec; 8] =
    [&TREE, &TREE_DEDUP, &TREE_FENWICK, &PAIR, &RLEVEL, &PRSVM, &PRSVM_TREE, &TOPPUSH];

/// Look a loss up by canonical name or alias.
pub fn find(name: &str) -> Option<&'static LossSpec> {
    SPECS.iter().copied().find(|s| s.name == name || s.aliases.contains(&name))
}

/// Canonical names of every registered loss, registry order.
pub fn names() -> impl Iterator<Item = &'static str> {
    SPECS.iter().map(|s| s.name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn find_resolves_names_and_aliases() {
        for spec in SPECS {
            assert!(std::ptr::eq(find(spec.name).unwrap(), spec));
            for a in spec.aliases {
                assert!(std::ptr::eq(find(a).unwrap(), spec), "alias {a}");
            }
        }
        assert!(find("nope").is_none());
    }

    #[test]
    fn names_and_aliases_are_unique() {
        let mut seen = std::collections::HashSet::new();
        for spec in SPECS {
            assert!(seen.insert(spec.name), "duplicate name {}", spec.name);
            for a in spec.aliases {
                assert!(seen.insert(a), "duplicate alias {a}");
            }
        }
    }

    #[test]
    fn solver_family_matches_constructor_shape() {
        for spec in SPECS {
            match spec.solver {
                SolverFamily::Bmrm => {
                    assert!(spec.bmrm.is_some() && spec.newton.is_none(), "{}", spec.name)
                }
                SolverFamily::Newton => {
                    assert!(spec.bmrm.is_none() && spec.newton.is_some(), "{}", spec.name)
                }
            }
        }
    }
}
