//! Figure 4 — test pairwise ranking error vs training-set size for the
//! different implementations (sanity check: all methods reach similar
//! solutions despite implementation differences; PRSVM optimizes a
//! squared hinge yet lands at similar test error).
//!
//! Paper protocol: held-out test sets (4000 for Cadata, 20000 for
//! Reuters), fixed λ per dataset. PairRSVM is omitted as in the paper
//! (identical solution to TreeRSVM by construction — asserted in the
//! test suite instead).

mod common;

use common::{full_scale, header, record};
use ranksvm::coordinator::{evaluate, train, Method, TrainConfig};
use ranksvm::data::{synthetic, Dataset};
use ranksvm::util::json::Json;

fn panel(
    name: &str,
    make: &dyn Fn(usize) -> Dataset,
    sizes: &[usize],
    test_size: usize,
    lambda: f64,
    prsvm_cap: usize,
) {
    header(&format!("Fig 4 ({name}): test pairwise error vs m (λ={lambda}, test={test_size})"));
    let methods = [Method::Tree, Method::RLevel, Method::Prsvm];
    print!("{:>9}", "m");
    for m in &methods {
        print!(" {:>12}", m.name());
    }
    println!();
    // One big pool split once: test set fixed across training sizes.
    let max_m = *sizes.last().unwrap();
    let pool = make(max_m + test_size);
    let (train_pool, test_ds) = pool.split(test_size, 17);
    for &m in sizes {
        let tr = train_pool.prefix(m);
        print!("{m:>9}");
        for &method in &methods {
            if method == Method::Prsvm && m > prsvm_cap {
                print!(" {:>12}", "(skipped)");
                continue;
            }
            let cfg = TrainConfig { method, lambda, epsilon: 1e-3, ..Default::default() };
            let out = train(&tr, &cfg).expect("training failed");
            let err = evaluate(&out.model, &test_ds);
            print!(" {err:>12.4}");
            record(
                "fig4_test_error",
                Json::obj(vec![
                    ("panel", name.into()),
                    ("m", m.into()),
                    ("method", method.name().into()),
                    ("test_error", err.into()),
                ]),
            );
        }
        println!();
    }
}

fn main() {
    let full = full_scale();
    let cadata_sizes = vec![1000, 2000, 4000, 8000, 16000];
    let reuters_sizes: Vec<usize> = if full {
        vec![1000, 2000, 4000, 8000, 16000, 32000, 64000]
    } else {
        vec![1000, 2000, 4000, 8000]
    };
    let (cadata_test, reuters_test) = if full { (4000, 20000) } else { (4000, 5000) };
    let prsvm_cap = if full { 8000 } else { 4000 };

    panel(
        "cadata",
        &|m| synthetic::cadata_like(m, 100),
        &cadata_sizes,
        cadata_test,
        1e-1,
        prsvm_cap,
    );
    panel(
        "reuters",
        &|m| synthetic::reuters_like(m, 200),
        &reuters_sizes,
        reuters_test,
        1e-5,
        prsvm_cap,
    );

    println!("\nExpected shape (paper): curves for all methods nearly coincide and");
    println!("decrease with m — the implementations reach equivalent solutions.");
}
