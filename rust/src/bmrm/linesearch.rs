//! 1-D line search used by the optional OCAS-style BMRM variant.
//!
//! The paper's §6 names "a line search procedure similar to the one
//! proposed by Franc and Sonnenburg (2009)" as future work; we implement
//! it as golden-section search over `β ∈ [lo, hi]` on the segment between
//! the best-so-far iterate and the master-problem solution. `J` restricted
//! to the segment is convex (sum of a convex risk in affine scores and a
//! quadratic), so golden-section converges linearly to the segment
//! minimum without derivatives.

/// Golden-section minimization of a convex `f` over `[lo, hi]` with
/// `iters` interval reductions. Returns the argmin estimate; with
/// `iters = 12` the bracket shrinks below 0.01·(hi−lo). The endpoints are
/// also probed so the result is never worse than `min(f(lo), f(hi))` up
/// to bracketing error.
pub fn golden_section(mut f: impl FnMut(f64) -> f64, lo: f64, hi: f64, iters: usize) -> f64 {
    debug_assert!(hi > lo);
    const INV_PHI: f64 = 0.618_033_988_749_894_8; // 1/φ
    let mut a = lo;
    let mut b = hi;
    let mut c = b - (b - a) * INV_PHI;
    let mut d = a + (b - a) * INV_PHI;
    let mut fc = f(c);
    let mut fd = f(d);
    for _ in 0..iters {
        if fc < fd {
            b = d;
            d = c;
            fd = fc;
            c = b - (b - a) * INV_PHI;
            fc = f(c);
        } else {
            a = c;
            c = d;
            fc = fd;
            d = a + (b - a) * INV_PHI;
            fd = f(d);
        }
    }
    let mid = 0.5 * (a + b);
    // Guard against flat/boundary optima: compare against the endpoints.
    let candidates = [(mid, f(mid)), (lo, f(lo)), (hi, f(hi))];
    candidates
        .iter()
        .min_by(|x, y| x.1.total_cmp(&y.1))
        .unwrap()
        .0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_quadratic_minimum() {
        let x = golden_section(|b| (b - 0.3) * (b - 0.3), 0.0, 1.0, 30);
        assert!((x - 0.3).abs() < 1e-4, "{x}");
    }

    #[test]
    fn boundary_minimum_left() {
        let x = golden_section(|b| b, 0.0, 1.0, 20);
        assert!(x < 0.01, "{x}");
    }

    #[test]
    fn boundary_minimum_right() {
        let x = golden_section(|b| -b, 0.0, 1.0, 20);
        assert!(x > 0.99, "{x}");
    }

    #[test]
    fn piecewise_linear_convex() {
        // V-shaped hinge at 0.7.
        let x = golden_section(|b: f64| (b - 0.7).abs(), 0.0, 1.0, 30);
        assert!((x - 0.7).abs() < 1e-3, "{x}");
    }

    #[test]
    fn counts_probes_economically() {
        let mut calls = 0;
        let _ = golden_section(
            |b| {
                calls += 1;
                b * b
            },
            0.0,
            1.0,
            12,
        );
        // 2 initial + 12 iterations + 3 final guards = 17.
        assert_eq!(calls, 17);
    }
}
