"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and value regimes; every comparison is
assert_allclose at f32-appropriate tolerances (the kernels and the refs
use different contraction orders).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import grad, pair_count, ref, scores

RTOL = 3e-4
ATOL = 1e-4


def _rng(seed):
    return np.random.default_rng(seed)


# Block sizes must divide m; sample m as multiple of the block.
blocks = st.sampled_from([8, 16, 64, 128])
multipliers = st.integers(min_value=1, max_value=6)
feature_dims = st.sampled_from([1, 3, 8, 17, 64])
seeds = st.integers(min_value=0, max_value=2**31 - 1)


@settings(max_examples=40, deadline=None)
@given(block=blocks, mult=multipliers, n=feature_dims, seed=seeds)
def test_scores_matches_ref(block, mult, n, seed):
    m = block * mult
    r = _rng(seed)
    x = jnp.asarray(r.normal(size=(m, n)).astype(np.float32))
    w = jnp.asarray(r.normal(size=(n,)).astype(np.float32))
    got = scores.scores(x, w, block_m=block)
    want = ref.scores_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@settings(max_examples=40, deadline=None)
@given(block=blocks, mult=multipliers, n=feature_dims, seed=seeds)
def test_grad_matches_ref(block, mult, n, seed):
    m = block * mult
    r = _rng(seed)
    x = jnp.asarray(r.normal(size=(m, n)).astype(np.float32))
    c = jnp.asarray(r.normal(size=(m,)).astype(np.float32))
    got = grad.grad(x, c, block_m=block)
    want = ref.grad_ref(x, c)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL * np.sqrt(m))


@settings(max_examples=25, deadline=None)
@given(
    block=st.sampled_from([8, 32, 64]),
    mult=st.integers(min_value=1, max_value=4),
    seed=seeds,
    label_kind=st.sampled_from(["real", "levels", "bipartite", "tied"]),
    pad=st.integers(min_value=0, max_value=7),
)
def test_pair_count_matches_ref(block, mult, seed, label_kind, pad):
    m = block * mult
    r = _rng(seed)
    p = jnp.asarray(r.normal(size=(m,)).astype(np.float32))
    if label_kind == "real":
        y = r.normal(size=(m,))
    elif label_kind == "levels":
        y = r.integers(0, 5, size=(m,))
    elif label_kind == "bipartite":
        y = r.integers(0, 2, size=(m,))
    else:
        y = np.zeros((m,))
    y = jnp.asarray(y.astype(np.float32))
    pad = min(pad, m - 1)
    valid = jnp.asarray((np.arange(m) < m - pad).astype(np.float32))
    c1, d1 = pair_count.pair_count(p, y, valid, block=block)
    c2, d2 = ref.pair_count_ref(p, y, valid)
    # Counts are small integers in f32 — exact equality holds for m ≤ a few
    # thousand (well below 2^24).
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))


def test_pair_count_symmetry():
    """Σc == Σd: each violating pair is counted once on each side."""
    r = _rng(7)
    m = 128
    p = jnp.asarray(r.normal(size=(m,)).astype(np.float32))
    y = jnp.asarray(r.normal(size=(m,)).astype(np.float32))
    v = jnp.ones((m,), jnp.float32)
    c, d = pair_count.pair_count(p, y, v, block=32)
    assert float(jnp.sum(c)) == pytest.approx(float(jnp.sum(d)))


def test_pair_count_padding_is_exact():
    """Padding rows must contribute nothing — compare padded vs unpadded."""
    r = _rng(11)
    m, pad_to = 48, 64
    p = r.normal(size=(m,)).astype(np.float32)
    y = r.normal(size=(m,)).astype(np.float32)
    c_small, d_small = pair_count.pair_count(
        jnp.asarray(p), jnp.asarray(y), jnp.ones((m,), jnp.float32), block=16
    )
    p_pad = np.zeros((pad_to,), np.float32)
    y_pad = np.zeros((pad_to,), np.float32)
    p_pad[:m], y_pad[:m] = p, y
    valid = (np.arange(pad_to) < m).astype(np.float32)
    c_pad, d_pad = pair_count.pair_count(
        jnp.asarray(p_pad), jnp.asarray(y_pad), jnp.asarray(valid), block=16
    )
    np.testing.assert_array_equal(np.asarray(c_pad)[:m], np.asarray(c_small))
    np.testing.assert_array_equal(np.asarray(d_pad)[:m], np.asarray(d_small))
    np.testing.assert_array_equal(np.asarray(c_pad)[m:], 0.0)
    np.testing.assert_array_equal(np.asarray(d_pad)[m:], 0.0)


def test_scores_rejects_indivisible_block():
    x = jnp.zeros((10, 3), jnp.float32)
    w = jnp.zeros((3,), jnp.float32)
    with pytest.raises(ValueError):
        scores.scores(x, w, block_m=4)


def test_margin_boundary_is_strict():
    """p_i == p_j − 1 exactly: not a violation (eq. 5 strict inequality)."""
    p = jnp.asarray(np.array([-1.0, 0.0], np.float32))
    y = jnp.asarray(np.array([0.0, 1.0], np.float32))
    v = jnp.ones((2,), jnp.float32)
    c, d = pair_count.pair_count(p, y, v, block=2)
    np.testing.assert_array_equal(np.asarray(c), 0.0)
    np.testing.assert_array_equal(np.asarray(d), 0.0)
