//! Fenwick-tree (binary indexed tree) alternative to the order-statistics
//! red-black tree.
//!
//! Algorithm 3 only ever inserts keys drawn from the *known* set of
//! training utility scores, so the key universe can be rank-compressed
//! once per training run (`O(m log m)` — already paid by the sort in
//! Theorem 3). After compression, insert / count-smaller / count-larger
//! are `O(log r)` prefix-sum updates over an implicit tree of `r`
//! counters — same asymptotics as the red-black tree but with a flat
//! array, no rotations, and no pointer chasing. `ablation_tree` measures
//! the constant-factor difference; the RB tree remains the faithful
//! reproduction of the paper (it needs no a-priori key universe).

/// Collapse `−0.0` onto `+0.0` so the `total_cmp` rank order matches
/// the numeric comparisons ([`count_smaller`](FenwickCounter::count_smaller)
/// treats them as the tie they are numerically).
#[inline]
fn canon(key: f64) -> f64 {
    if key == 0.0 {
        0.0
    } else {
        key
    }
}

/// Rank-compressed Fenwick counter over a fixed key universe.
#[derive(Clone, Debug)]
pub struct FenwickCounter {
    /// Sorted, deduplicated key universe.
    keys: Vec<f64>,
    /// 1-based Fenwick array of multiplicities.
    tree: Vec<u64>,
    len: u64,
}

impl FenwickCounter {
    /// Build from the (not necessarily sorted or unique) key universe.
    /// Keys inserted later must come from this universe.
    pub fn new(universe: &[f64]) -> Self {
        let mut keys: Vec<f64> = universe.iter().map(|&k| canon(k)).collect();
        // total_cmp: a NaN in the universe sorts (deterministically) to
        // the end instead of panicking; on canonicalized keys the total
        // order agrees with the numeric order the counters implement.
        keys.sort_unstable_by(|a, b| a.total_cmp(b));
        keys.dedup();
        let r = keys.len();
        FenwickCounter { keys, tree: vec![0; r + 1], len: 0 }
    }

    /// Number of distinct keys in the universe (the paper's `r`).
    pub fn universe_size(&self) -> usize {
        self.keys.len()
    }

    pub fn len(&self) -> u64 {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Reset all counters, keeping the compressed universe.
    pub fn clear(&mut self) {
        self.tree.iter_mut().for_each(|c| *c = 0);
        self.len = 0;
    }

    /// Rank of `key` in the universe (0-based). Panics if absent.
    #[inline]
    fn rank(&self, key: f64) -> usize {
        let key = canon(key);
        self.keys
            .binary_search_by(|probe| probe.total_cmp(&key))
            .unwrap_or_else(|_| panic!("key {key} not in the compressed universe"))
    }

    /// Insert one occurrence of `key`. `O(log r)`.
    pub fn insert(&mut self, key: f64) {
        let mut i = self.rank(key) + 1;
        while i < self.tree.len() {
            self.tree[i] += 1;
            i += i & i.wrapping_neg();
        }
        self.len += 1;
    }

    /// Prefix sum of multiplicities over ranks `1..=i` (1-based).
    #[inline]
    fn prefix(&self, mut i: usize) -> u64 {
        let mut s = 0;
        while i > 0 {
            s += self.tree[i];
            i -= i & i.wrapping_neg();
        }
        s
    }

    /// Number of inserted keys strictly smaller than `key`. The query key
    /// must also be in the universe (true in Algorithm 3, where queries
    /// are training labels). `O(log r)`.
    pub fn count_smaller(&self, key: f64) -> u64 {
        self.prefix(self.rank(key))
    }

    /// Number of inserted keys strictly larger than `key`. `O(log r)`.
    pub fn count_larger(&self, key: f64) -> u64 {
        self.len - self.prefix(self.rank(key) + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn matches_naive_counts() {
        let mut rng = Rng::new(31);
        for _ in 0..30 {
            let m = 1 + rng.below(300);
            let universe_n = 1 + rng.below(40);
            let universe: Vec<f64> = (0..universe_n).map(|i| i as f64 * 0.5 - 3.0).collect();
            let mut f = FenwickCounter::new(&universe);
            let mut inserted: Vec<f64> = Vec::new();
            for _ in 0..m {
                let k = universe[rng.below(universe_n)];
                f.insert(k);
                inserted.push(k);
            }
            for &q in universe.iter() {
                let naive_s = inserted.iter().filter(|&&x| x < q).count() as u64;
                let naive_l = inserted.iter().filter(|&&x| x > q).count() as u64;
                assert_eq!(f.count_smaller(q), naive_s);
                assert_eq!(f.count_larger(q), naive_l);
            }
        }
    }

    #[test]
    fn agrees_with_ostree() {
        use crate::rbtree::OsTree;
        let mut rng = Rng::new(37);
        let universe: Vec<f64> = (0..64).map(|_| rng.normal()).collect();
        let mut f = FenwickCounter::new(&universe);
        let mut t = OsTree::new();
        for _ in 0..500 {
            let k = universe[rng.below(universe.len())];
            f.insert(k);
            t.insert(k);
        }
        for &q in &universe {
            assert_eq!(f.count_smaller(q), t.count_smaller(q));
            assert_eq!(f.count_larger(q), t.count_larger(q));
        }
    }

    #[test]
    fn clear_keeps_universe() {
        let mut f = FenwickCounter::new(&[1.0, 2.0, 3.0, 2.0]);
        assert_eq!(f.universe_size(), 3);
        f.insert(2.0);
        f.insert(3.0);
        assert_eq!(f.count_smaller(3.0), 1);
        f.clear();
        assert!(f.is_empty());
        assert_eq!(f.count_smaller(3.0), 0);
        f.insert(1.0);
        assert_eq!(f.count_larger(1.0), 0);
        assert_eq!(f.count_smaller(2.0), 1);
    }

    #[test]
    #[should_panic]
    fn foreign_key_panics() {
        let mut f = FenwickCounter::new(&[1.0, 2.0]);
        f.insert(5.0);
    }

    #[test]
    fn empty_universe() {
        let f = FenwickCounter::new(&[]);
        assert_eq!(f.universe_size(), 0);
        assert!(f.is_empty());
    }
}
